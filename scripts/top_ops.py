"""List the top flops/bytes contributors of a saved HLO module, trip-aware.

    PYTHONPATH=src python scripts/top_ops.py <module.hlo.txt> [flops|bytes] [N]
"""

import re
import sys

sys.path.insert(0, "src")
from repro.launch.hlo_analysis import (  # noqa: E402
    _TRIP_RE, _LHS_CONTRACT_RE, _FIRST_OPERAND_RE,
    _first_shape_dims, _parse_instr, _shape_bytes, parse_computations,
)


def main(path, mode="flops", topn=15):
    txt = open(path).read()
    comps, entry = parse_computations(txt)
    symtab = {}
    for cname, lines in comps.items():
        tab = {}
        for ln in lines:
            pi = _parse_instr(ln)
            if pi:
                tab[pi[0]] = pi[1]
        symtab[cname] = tab

    # computation -> total trip multiplier (walk from entry)
    mult = {entry: 1}
    stack = [entry]
    while stack:
        c = stack.pop()
        for ln in comps.get(c, []):
            pi = _parse_instr(ln)
            if not pi:
                continue
            _, _, op, after = pi
            if op == "while":
                tm = _TRIP_RE.search(ln)
                t = int(tm.group(1)) if tm else 1
                for pat in (r"body=%([\w\.\-]+)", r"condition=%([\w\.\-]+)"):
                    m = re.search(pat, ln)
                    if m and m.group(1) not in mult:
                        mult[m.group(1)] = mult.get(c, 1) * t
                        stack.append(m.group(1))
            else:
                m = re.search(r"(?:calls|to_apply)=%([\w\.\-]+)", after)
                if m and m.group(1) not in mult:
                    mult[m.group(1)] = mult.get(c, 1)
                    stack.append(m.group(1))

    items = []
    for cname, lines in comps.items():
        m = mult.get(cname, 0)
        if not m:
            continue
        tab = symtab[cname]
        for ln in lines:
            pi = _parse_instr(ln)
            if not pi:
                continue
            name, rtype, op, after = pi
            if mode == "flops":
                if op != "dot":
                    continue
                dims = _first_shape_dims(rtype) or []
                f = 2.0
                for d in dims:
                    f *= d
                cm = _LHS_CONTRACT_RE.search(after)
                om = _FIRST_OPERAND_RE.search(after)
                lhs = ""
                if cm and om:
                    lhs = tab.get(om.group(1), "")
                    ld = _first_shape_dims(lhs) or []
                    for i in (int(i) for i in cm.group(1).split(",") if i):
                        if i < len(ld):
                            f *= ld[i]
                meta = re.search(r'op_name="([^"]+)"', ln)
                items.append((f * m, m, rtype[:40], lhs[:34],
                              (meta.group(1).split("/")[-2:] if meta else ["?"])))
            else:
                if op in ("tuple", "get-tuple-element", "parameter", "bitcast",
                          "while", "constant", "iota", "reshape", "call"):
                    continue
                items.append((2 * _shape_bytes(rtype) * m, m, op, rtype[:50],
                              [cname[:30]]))
    items.sort(reverse=True)
    total = sum(i[0] for i in items)
    print(f"total {mode}: {total:.4e}")
    for val, m, a, b, meta in items[:topn]:
        print(f"{val:.3e} x{m:<5d} {a:<42s} {b:<36s} {'/'.join(str(x) for x in meta)}")


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2] if len(sys.argv) > 2 else "flops",
         int(sys.argv[3]) if len(sys.argv) > 3 else 15)
