"""Obs-artifact validator: the CI `obs-smoke` gate.

Takes the files a served workload wrote (`launch.serve --metrics-dump` /
`--trace-out`) and refuses anything malformed:

  * **Prometheus dumps** — must parse under the STRICT
    ``repro.obs.parse_prometheus_text`` (every non-comment line a valid
    sample), and must cover the documented name families: at least one
    ``serve_*``, ``plan_cache_*`` sample (``kv_*`` too when the workload
    ran a paged engine — checked when present).
  * **JSON snapshots** — ``{"metrics": {series: {"kind", "value"}}}`` with
    every kind one of counter/gauge/histogram and histogram values
    carrying consistent edges/counts/count.
  * **JSONL traces** — every line schema-checked (type/name/id/parent/rid/
    t0/attrs; spans also t1), ids strictly increasing, parents resolving
    to earlier spans, every span closed (t1 >= t0), and each traced
    request carrying the full documented taxonomy: a ``request`` span with
    ``queued`` child, a terminal status, and — for served requests — a
    ``first_token`` event between ``prefill`` and ``decode``.

    PYTHONPATH=src python scripts/check_obs.py \
        --prom metrics.prom --json metrics.json --trace trace.jsonl
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def check_prom(path: str) -> list:
    from repro.obs import parse_prometheus_text

    errors = []
    try:
        samples = parse_prometheus_text(Path(path).read_text())
    except ValueError as e:
        return [f"{path}: {e}"]
    if not samples:
        return [f"{path}: no samples at all"]
    for family in ("serve_", "plan_cache_"):
        if not any(name.startswith(family) for name in samples):
            errors.append(f"{path}: no {family}* samples")
    # histogram exposition consistency: every _bucket family needs its
    # _count, and the +Inf bucket must equal it
    for name, v in samples.items():
        if '_bucket{le="+Inf"}' in name:
            base = name.split("_bucket{")[0]
            count = samples.get(f"{base}_count")
            if count is None:
                errors.append(f"{path}: {base}_bucket without {base}_count")
            elif v != count:
                errors.append(f"{path}: {base} +Inf bucket {v} != count "
                              f"{count}")
    print(f"{path}: {len(samples)} samples ok")
    return errors


def check_json(path: str) -> list:
    errors = []
    doc = json.loads(Path(path).read_text())
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        return [f"{path}: missing/empty 'metrics' object"]
    for series, entry in metrics.items():
        kind = entry.get("kind")
        if kind not in ("counter", "gauge", "histogram"):
            errors.append(f"{path}: {series}: bad kind {kind!r}")
            continue
        v = entry.get("value")
        if kind == "histogram":
            if (not isinstance(v, dict)
                    or len(v.get("counts", [])) != len(v.get("edges", [])) + 1
                    or sum(v["counts"]) != v.get("count")):
                errors.append(f"{path}: {series}: inconsistent histogram")
        elif not isinstance(v, (int, float)):
            errors.append(f"{path}: {series}: non-numeric value {v!r}")
    print(f"{path}: {len(metrics)} series ok")
    return errors


SPAN_KEYS = {"type", "name", "id", "parent", "rid", "t0", "t1", "attrs"}
EVENT_KEYS = {"type", "name", "id", "parent", "rid", "t0", "attrs"}


def check_trace(path: str) -> list:
    errors = []
    spans: dict = {}
    by_rid: dict = {}
    last_id = -1
    for i, line in enumerate(Path(path).read_text().splitlines(), 1):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"{path}:{i}: not JSON: {e}")
            continue
        t = rec.get("type")
        if t == "meta":
            continue
        want = SPAN_KEYS if t == "span" else EVENT_KEYS
        if t not in ("span", "event") or set(rec) != want:
            errors.append(f"{path}:{i}: bad record shape: {sorted(rec)}")
            continue
        if rec["id"] <= last_id:
            errors.append(f"{path}:{i}: ids not strictly increasing")
        last_id = rec["id"]
        if rec["parent"] is not None and rec["parent"] not in spans:
            errors.append(f"{path}:{i}: parent {rec['parent']} not an "
                          f"earlier span")
        if t == "span":
            if rec["t1"] is None or rec["t1"] < rec["t0"]:
                errors.append(f"{path}:{i}: span {rec['name']}#{rec['id']} "
                              f"not closed or negative ({rec['t1']})")
            spans[rec["id"]] = rec
        if rec["rid"] is not None:
            by_rid.setdefault(rec["rid"], {}).setdefault(
                rec["name"], []).append(rec)
    if not by_rid:
        errors.append(f"{path}: no per-request records at all")
    for rid, names in sorted(by_rid.items()):
        if "request" not in names or "queued" not in names:
            errors.append(f"{path}: rid {rid}: missing request/queued span")
            continue
        status = names["request"][0]["attrs"].get("status")
        if status not in ("done", "expired"):
            errors.append(f"{path}: rid {rid}: bad terminal status {status!r}")
        if status == "done":
            for name in ("prefill", "first_token", "decode"):
                if name not in names:
                    errors.append(f"{path}: rid {rid}: served request "
                                  f"missing {name}")
    print(f"{path}: {last_id + 1} records, {len(by_rid)} requests ok")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prom", action="append", default=[],
                    help="Prometheus text dump(s) to validate")
    ap.add_argument("--json", action="append", default=[], dest="json_",
                    help="JSON metrics snapshot(s) to validate")
    ap.add_argument("--trace", action="append", default=[],
                    help="JSONL trace file(s) to validate")
    args = ap.parse_args()
    if not (args.prom or args.json_ or args.trace):
        ap.error("nothing to check: pass --prom/--json/--trace")
    errors = []
    for p in args.prom:
        errors += check_prom(p)
    for p in args.json_:
        errors += check_json(p)
    for p in args.trace:
        errors += check_trace(p)
    if errors:
        print("\nFAIL:")
        for e in errors:
            print(f"  {e}")
        return 1
    print("obs artifacts OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
