"""Docs health check: links resolve, anchors exist, snippets actually run.

Two failure modes make documentation rot silently: a moved/renamed file
leaves dangling intra-repo links, and an API change leaves quickstart
snippets that no longer execute.  This script closes both for `README.md`
and every markdown file under `docs/`:

  * **links** — every relative markdown link `[text](path#anchor)` must
    point at an existing file inside the repo, and, when it carries an
    anchor, at a real heading of the target file (GitHub-style slugs,
    including the `-1` suffixes for duplicate headings).  External links
    (`http://`, `https://`, `mailto:`) are skipped — this is an offline
    check.
  * **snippets** — every fenced code block tagged ```` ```python ```` is
    executed (Pallas kernels auto-select interpret mode off-TPU, so the
    snippets run on a CPU container).  Blocks in the same file share one
    namespace, so a later block may build on an earlier one's imports.
    Tag a block ```` ```python no-run ```` to document code the check
    must not execute (e.g. the tune walkthrough, which trains a network).

CI runs this as the `docs` job:

    PYTHONPATH=src python scripts/check_docs.py
"""

from __future__ import annotations

import re
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md", *sorted(
    p.relative_to(REPO).as_posix() for p in (REPO / "docs").glob("**/*.md")
)]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")
FENCE_RE = re.compile(r"^```(.*)$")


def strip_code_blocks(text: str) -> str:
    """Remove fenced blocks so code-looking brackets aren't parsed as links."""
    out, in_fence = [], False
    for line in text.splitlines():
        if FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def heading_slugs(md_text: str) -> set:
    """GitHub-style anchor slugs for every heading (with -N dedup suffixes)."""
    slugs: set = set()
    counts: dict = {}
    for line in strip_code_blocks(md_text).splitlines():
        m = re.match(r"^#{1,6}\s+(.*)$", line)
        if not m:
            continue
        raw = re.sub(r"`([^`]*)`", r"\1", m.group(1).strip())  # drop code ticks
        slug = re.sub(r"[^\w\- ]", "", raw.lower(), flags=re.UNICODE)
        slug = slug.replace(" ", "-")
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_links(relpath: str, text: str, slug_cache: dict) -> list:
    errors = []
    base = (REPO / relpath).parent
    for target in LINK_RE.findall(strip_code_blocks(text)):
        target = target.split()[0].strip("<>")  # drop "title" suffixes
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = (REPO / relpath) if not path_part else (base / path_part)
        try:
            dest = dest.resolve()
            dest.relative_to(REPO)
        except ValueError:
            errors.append(f"{relpath}: link escapes the repo: {target}")
            continue
        if not dest.exists():
            errors.append(f"{relpath}: broken link: {target}")
            continue
        if anchor:
            if dest.suffix.lower() != ".md":
                errors.append(
                    f"{relpath}: anchor on non-markdown target: {target}"
                )
                continue
            if dest not in slug_cache:
                slug_cache[dest] = heading_slugs(
                    dest.read_text(encoding="utf-8")
                )
            if anchor.lower() not in slug_cache[dest]:
                errors.append(
                    f"{relpath}: missing anchor #{anchor} in "
                    f"{dest.relative_to(REPO).as_posix()}"
                )
    return errors


def iter_snippets(text: str):
    """Yield (info_string, first_line_no, source) for every fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m:
            info = m.group(1).strip()
            body, start = [], i + 2  # 1-indexed first body line
            i += 1
            while i < len(lines) and not FENCE_RE.match(lines[i]):
                body.append(lines[i])
                i += 1
            yield info, start, "\n".join(body)
        i += 1


def run_snippets(relpath: str, text: str) -> tuple:
    """Execute the runnable python blocks of one file; returns (ran, errors)."""
    ran, errors = 0, []
    namespace: dict = {"__name__": f"docs_snippet[{relpath}]"}
    for info, line, src in iter_snippets(text):
        tags = info.split()
        if not tags or tags[0] != "python" or "no-run" in tags:
            continue
        t0 = time.perf_counter()
        try:
            exec(compile(src, f"{relpath}:{line}", "exec"), namespace)
            ran += 1
            print(f"  snippet {relpath}:{line} ok "
                  f"({time.perf_counter() - t0:.1f}s)")
        except Exception as e:  # noqa: BLE001 — report, don't crash the check
            errors.append(f"{relpath}:{line}: snippet failed: {e!r}")
    return ran, errors


def main() -> int:
    sys.path.insert(0, str(REPO / "src"))
    all_errors: list = []
    slug_cache: dict = {}
    total_links = total_snips = 0
    for relpath in DOC_FILES:
        text = (REPO / relpath).read_text(encoding="utf-8")
        link_errors = check_links(relpath, text, slug_cache)
        n_links = len(LINK_RE.findall(strip_code_blocks(text)))
        total_links += n_links
        print(f"{relpath}: {n_links} links, "
              f"{len(link_errors)} broken")
        all_errors += link_errors
        ran, snip_errors = run_snippets(relpath, text)
        total_snips += ran
        all_errors += snip_errors
    print(f"checked {len(DOC_FILES)} files: {total_links} links, "
          f"{total_snips} snippets executed")
    if all_errors:
        print("\nFAIL:")
        for e in all_errors:
            print(f"  {e}")
        return 1
    print("docs OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
