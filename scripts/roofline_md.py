"""Render the §Roofline markdown table from reports/dryrun/*.json and patch
EXPERIMENTS.md (replaces FINAL_TABLE_PLACEHOLDER or the previous table).

    PYTHONPATH=src python scripts/roofline_md.py [reports/dryrun]
"""

import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import load_reports, model_flops  # noqa: E402
from repro.configs.registry import get_config  # noqa: E402

BEGIN = "<!-- ROOFLINE_TABLE_BEGIN -->"
END = "<!-- ROOFLINE_TABLE_END -->"


def render(directory="reports/dryrun"):
    reports = load_reports(directory)
    lines = [
        BEGIN,
        "",
        "| arch | shape | mesh | flops/dev | peak GiB | coll GiB | compute s | memory s | coll s | dominant | frac | useful |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    singles = [r for r in reports if len(r["mesh"]) == 2]
    multis = [r for r in reports if len(r["mesh"]) == 3]
    for rs in (singles, multis):
        for r in sorted(rs, key=lambda r: (r["arch"], r["shape"])):
            cfg = get_config(r["arch"])
            mf = model_flops(cfg, r["shape"])
            useful = mf / (r["flops_per_dev"] * r["devices"]) \
                if r["flops_per_dev"] else 0.0
            rl = r["roofline"]
            mesh = "x".join(str(m) for m in r["mesh"])
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} "
                f"| {r['flops_per_dev']:.2e} "
                f"| {r['memory'].get('peak_bytes', 0)/2**30:.1f} "
                f"| {r['collectives']['total']/2**30:.1f} "
                f"| {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
                f"| {rl['collective_s']:.3f} | {rl['dominant'].replace('_s','')} "
                f"| {rl['roofline_fraction']:.3f} | {min(useful, 9.99):.2f} |"
            )
    lines += ["", END]
    return "\n".join(lines)


def main():
    directory = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun"
    table = render(directory)
    text = open("EXPERIMENTS.md").read()
    if BEGIN in text:
        pre = text.split(BEGIN)[0]
        post = text.split(END)[1]
        text = pre + table + post
    elif "FINAL_TABLE_PLACEHOLDER" in text:
        text = text.replace("FINAL_TABLE_PLACEHOLDER", "\n\n" + table + "\n")
    else:
        text += "\n" + table + "\n"
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md updated with",
          table.count("\n") - 5, "rows")


if __name__ == "__main__":
    main()
