"""Serve a small KAN-FFN LM with batched requests (continuous batching).

The paper's kind is edge INFERENCE, so the end-to-end driver is serving: a
smoke-scale qwen2.5 backbone with the paper's KAN-FFN layers, briefly
trained, then served through the slot-based engine with a batch of prompts —
float path vs the fused quantized pipeline (same tokens), then once more
through the async scheduler with staggered arrivals, per-token streaming
and seeded sampling (docs/serving.md).

    PYTHONPATH=src python examples/serve_demo.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.data.lm_data import DataConfig, global_batch_at_step
from repro.models.model import init_params, loss_fn
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import SamplingParams, Scheduler
from repro.train.optimizer import adamw, apply_updates


def main():
    # smoke-scale backbone with the paper's technique as the FFN
    cfg = dataclasses.replace(
        smoke_config("qwen2.5-14b").kan_variant(grid=8), num_layers=2,
    )
    print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model} "
          f"ffn={cfg.ffn_kind} G={cfg.kan_grid})")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)

    # brief training so generations aren't pure noise
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    opt = adamw(3e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    print("training 30 steps ...")
    for s in range(30):
        b = {k: jnp.asarray(v) for k, v in global_batch_at_step(dcfg, s).items()}
        params, opt_state, loss = step(params, opt_state, b)
    print(f"final loss {float(loss):.3f}")

    # batched serving: 6 requests through 3 slots
    engine = ServeEngine(params, cfg, slots=3, max_len=64)
    rng = jax.random.PRNGKey(1)
    reqs = []
    for rid in range(6):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (8,), 3, cfg.vocab_size).tolist()
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=12))

    t0 = time.perf_counter()
    results = engine.run(reqs, log=print)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in results)
    print(f"\nserved {len(results)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on CPU)")
    for r in sorted(results, key=lambda r: r.rid):
        print(f"  req {r.rid}: {r.output}")

    # same batch on the paper's deployed datapath: FFN blocks ASP-quantized
    # at startup, every step through the fused kan_spline Pallas pipeline
    print("\nre-serving on the fused quantized pipeline (kan_deploy=True) ...")
    qengine = ServeEngine(params, cfg, slots=3, max_len=64, kan_deploy=True)
    qreqs = [Request(rid=r.rid, prompt=list(r.prompt), max_new_tokens=12)
             for r in sorted(results, key=lambda r: r.rid)]
    t0 = time.perf_counter()
    qresults = qengine.run(qreqs)
    dt = time.perf_counter() - t0
    same = sum(
        q.output == r.output
        for q, r in zip(sorted(qresults, key=lambda r: r.rid),
                        sorted(results, key=lambda r: r.rid))
    )
    qtokens = sum(len(r.output) for r in qresults)
    print(f"quantized path: {qtokens} tokens in {dt:.2f}s; "
          f"{same}/{len(qresults)} requests decode identical tokens")

    # async streaming serving: the same engine internals driven by the
    # event-driven scheduler — staggered arrivals, per-token callbacks,
    # seeded top-k sampling, TTFT/throughput metrics at shutdown
    print("\nstreaming sampled serving through the scheduler ...")
    sengine = ServeEngine(params, cfg, slots=3, max_len=64, kan_deploy=True)
    sched = Scheduler(sengine)
    sampling = SamplingParams(temperature=0.8, top_k=8, seed=0)
    streams: dict = {}
    rng = jax.random.PRNGKey(2)
    for rid in range(4):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (6,), 3, cfg.vocab_size).tolist()
        sched.submit(
            Request(rid=rid, prompt=prompt, max_new_tokens=10,
                    arrival_s=0.1 * rid, sampling=sampling),
            on_token=lambda r, tok: streams.setdefault(r.rid, []).append(tok),
        )
    sresults = sched.run_until_idle()
    assert all(streams[r.rid] == r.output for r in sresults)  # stream == final
    stats = sched.stats()
    print(f"streamed {stats['tokens']} tokens from {stats['completed']} "
          f"requests at {stats['tokens_per_s']:.1f} tok/s; "
          f"ttft p50 {stats['ttft_s']['p50'] * 1e3:.0f}ms, "
          f"itl p50 {stats['itl_s']['p50'] * 1e3:.1f}ms")
    for rid in sorted(streams):
        print(f"  req {rid} streamed: {streams[rid]}")


if __name__ == "__main__":
    main()
