"""End-to-end knot-theory pipeline (the paper's fig. 13 application):

train KAN on the knot surrogate -> ASP-quantize -> evaluate on the
RRAM-ACIM simulator with KAN-SAM mapping -> report accuracy + hardware cost.

    PYTHONPATH=src python examples/knot_e2e.py [--fast]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core.asp_quant import ASPQuantSpec
from repro.core.cim import CIMConfig
from repro.core.costmodel import accelerator_cost, kan_accelerator
from repro.core.kan_layer import KANSpec, param_count
from repro.core.neurosim import (
    evaluate_accuracy, evaluate_accuracy_cim, train_kan,
)
from repro.core.tmdv import TMDVConfig
from repro.data.knot import make_knot_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--grid", type=int, default=5)
    args = ap.parse_args()

    n = 8192 if args.fast else 32768
    epochs = 60 if args.fast else 250
    xt, yt, xv, yv = make_knot_dataset(n, 2048, seed=0, label_noise=0.04)
    kspec = KANSpec(dims=(17, 1, 14), grid_size=args.grid)
    print(f"training KAN {kspec.dims} G={args.grid} "
          f"({param_count(kspec)} params) on {n} samples ...")

    steps = epochs * max(1, n // 2048)

    def sched(step):
        t = jnp.minimum(step / (0.9 * steps), 1.0)
        return 1.5e-2 * 0.95 * (0.5 * (1 + jnp.cos(jnp.pi * t))) + 1e-3

    params, hist = train_kan(kspec, xt, yt, xv, yv, epochs=epochs,
                             batch_size=2048, lr=sched, verbose=True)
    sw = evaluate_accuracy(params, xv, yv, kspec)
    print(f"\nsoftware accuracy: {sw:.3f}")

    cim = CIMConfig(array_rows=128, adc_bits=8, ir_gamma=0.06, sigma_ps_ref=0.05)
    for sam in (False, True):
        acc = evaluate_accuracy_cim(params, xv, yv, kspec, cim,
                                    jax.random.PRNGKey(7), use_sam=sam,
                                    calib_x=xt[:2048])
        print(f"ACIM accuracy ({'KAN-SAM' if sam else 'baseline map'}): {acc:.3f}")

    spec = ASPQuantSpec(grid_size=args.grid, order=3, n_bits=8, lut_bits=8,
                        lo=-1.0, hi=1.0)
    cost = accelerator_cost(
        kan_accelerator((17, 1, 14), spec, TMDVConfig(8, 4), 128, adc_bits=8))
    print(f"\n22nm accelerator: {cost['area_mm2']*1e3:.1f} x1e-3 mm^2, "
          f"{cost['energy_pj']:.0f} pJ/inference, {cost['latency_ns']:.0f} ns")


if __name__ == "__main__":
    main()
