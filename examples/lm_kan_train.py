"""Train a small LM with KAN-FFN layers end-to-end through the production
TrainLoop (checkpointing, NaN guards, straggler watchdog, restart).

    PYTHONPATH=src python examples/lm_kan_train.py [--steps 60]
"""

import argparse
import dataclasses
import tempfile

from repro.configs.registry import smoke_config
from repro.data.lm_data import DataConfig
from repro.train.loop import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="qwen2.5-14b")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        smoke_config(args.arch).kan_variant(grid=8),
        num_layers=2, learning_rate=3e-3,
    )
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    ckpt_dir = tempfile.mkdtemp(prefix="kan_lm_ckpt_")
    print(f"arch={cfg.name} steps={args.steps} ckpt={ckpt_dir}")

    loop = TrainLoop(cfg, dcfg, ckpt_dir, ckpt_every=20)
    loop.install_sigterm_handler()
    hist = loop.run(args.steps, log_every=10)
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{len(hist)} steps; stragglers flagged: {loop.watchdog.straggler_steps}")

    # demonstrate restart: a second loop resumes from the checkpoint
    loop2 = TrainLoop(cfg, dcfg, ckpt_dir, ckpt_every=20)
    print(f"restart resumes at step {loop2.start_step}")
    loop2.run(10, log_every=5)


if __name__ == "__main__":
    main()
