"""KAN-NeuroSim hyperparameter search (paper §3.4, Fig. 9) via ``repro.tune``.

step 1 — Pareto search over the design space under each hardware budget
         (the old ad-hoc max-G loop, generalized: the same constraint check
         and cost model, but searching every knob and returning a front);
step 2 — grid-extension training under the budget with ACIM-aware eval.

    PYTHONPATH=src python examples/neurosim_search.py [--fast]
"""

import argparse

from repro.core.neurosim import (
    HardwareConstraints, evaluate_accuracy, grid_extension_train,
)
from repro.data.knot import make_knot_dataset
from repro.tune import DesignSpace, SearchConfig, pareto_search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    dims = (17, 1, 14)
    budgets = {
        "minimal (KAN1-like)": HardwareConstraints(
            max_area_mm2=0.016, max_energy_pj=280, max_latency_ns=700),
        "moderate (KAN2-like)": HardwareConstraints(
            max_area_mm2=0.065, max_energy_pj=420, max_latency_ns=900),
    }
    # step 1: cost-only design-space search (task=None -> no training), the
    # repro.tune generalization of the old search_max_grid loop: same cost
    # model + constraint check, but over G AND the TM-DV split, returning a
    # Pareto front instead of one max-G point.
    space = DesignSpace(
        grid_size=(3, 5, 8, 12, 16, 24, 32, 48, 68),
        voltage_bits=(3, 4, 5),
        array_rows=(128,),
        use_sam=(False,),  # SAM is cost-free; only meaningful with a task
    )
    for name, hc in budgets.items():
        res = pareto_search(
            None, space, constraints=hc, dims=dims,
            config=SearchConfig(budget=40, n_init=16, seed=0),
        )
        feas = [p for p in res.evaluated if p.feasible]
        if not feas:
            print(f"[{name}] infeasible")
            continue
        gmax = max(p.candidate.grid_size for p in feas)
        print(f"[{name}] step 1: {len(res.front)} Pareto points, "
              f"max feasible G = {gmax}")
        for p in res.front[:4]:
            c, m = p.candidate, p.metrics
            print(f"    G={c.grid_size:>2} vb={c.voltage_bits} "
                  f"area {m['area_mm2']:.4f} mm^2  {m['energy_pj']:.0f} pJ  "
                  f"{m['latency_ns']:.0f} ns")

    n = 8192 if args.fast else 16384
    xt, yt, xv, yv = make_knot_dataset(n, 2048, seed=0, label_noise=0.04)
    hc = budgets["minimal (KAN1-like)"]
    print("\nstep 2: grid-extension training under the minimal budget")
    out = grid_extension_train(
        dims, hc, xt, yt, xv, yv,
        g_init=3, extend_by=2,
        epochs_per_round=20 if args.fast else 60,
        max_rounds=3 if args.fast else 6,
    )
    print("extension log:", out["log"])
    acc = evaluate_accuracy(out["params"], xv, yv, out["kspec"])
    print(f"final: G={out['G']} accuracy={acc:.3f} "
          f"cost: {out['cost']['area_mm2']:.4f} mm^2 "
          f"{out['cost']['energy_pj']:.0f} pJ {out['cost']['latency_ns']:.0f} ns")


if __name__ == "__main__":
    main()
