"""KAN-NeuroSim hyperparameter search (paper §3.4, Fig. 9):

step 1 — find the largest grid G whose accelerator fits the hardware budget;
step 2 — grid-extension training under the budget with ACIM-aware eval.

    PYTHONPATH=src python examples/neurosim_search.py [--fast]
"""

import argparse

from repro.core.neurosim import (
    HardwareConstraints, grid_extension_train, search_max_grid,
)
from repro.data.knot import make_knot_dataset
from repro.core.neurosim import evaluate_accuracy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    dims = (17, 1, 14)
    budgets = {
        "minimal (KAN1-like)": HardwareConstraints(
            max_area_mm2=0.016, max_energy_pj=280, max_latency_ns=700),
        "moderate (KAN2-like)": HardwareConstraints(
            max_area_mm2=0.065, max_energy_pj=420, max_latency_ns=900),
    }
    for name, hc in budgets.items():
        g, cost = search_max_grid(dims, hc)
        print(f"[{name}] step 1: max G = {g}  "
              f"(area {cost['area_mm2']:.4f} mm^2, {cost['energy_pj']:.0f} pJ, "
              f"{cost['latency_ns']:.0f} ns)" if g else f"[{name}] infeasible")

    n = 8192 if args.fast else 16384
    xt, yt, xv, yv = make_knot_dataset(n, 2048, seed=0, label_noise=0.04)
    hc = budgets["minimal (KAN1-like)"]
    print("\nstep 2: grid-extension training under the minimal budget")
    out = grid_extension_train(
        dims, hc, xt, yt, xv, yv,
        g_init=3, extend_by=2,
        epochs_per_round=20 if args.fast else 60,
        max_rounds=3 if args.fast else 6,
    )
    print("extension log:", out["log"])
    acc = evaluate_accuracy(out["params"], xv, yv, out["kspec"])
    print(f"final: G={out['G']} accuracy={acc:.3f} "
          f"cost: {out['cost']['area_mm2']:.4f} mm^2 "
          f"{out['cost']['energy_pj']:.0f} pJ {out['cost']['latency_ns']:.0f} ns")


if __name__ == "__main__":
    main()
