"""End-to-end co-design tuning: search -> tile tune -> artifact -> deploy.

Runs the full ``repro.tune`` flow on the paper's KAN1 knot task:

  1. train the base network once, Pareto-search the design space under a
     KAN1-like hardware budget (cost model + acim-backend accuracy);
  2. pick an operating point off the front, deploy it, and tile-tune the
     fused Pallas pipeline for its geometry;
  3. dump a versioned tuning artifact, then RELOAD it into a cold runtime
     (caches cleared) and verify the deployment reproduces bit-identically
     — the file, not the search, is the deployment input from here on.

    PYTHONPATH=src python examples/tune_deploy.py [--smoke] [--out X.json]

Exit status is non-zero if the search returns an empty front or the
reloaded deployment mismatches — which is what the CI tuner smoke job
asserts on.  To serve an LM on the tuned point afterwards:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --kan-ffn --tuned-config TUNE_artifact.json
"""

import argparse
import sys

import jax
import numpy as np

from repro import runtime, tune
from repro.core.kan_network_deploy import kan_network_deploy_apply
from repro.core.neurosim import HardwareConstraints


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny budgets for CI: small task, few evals")
    ap.add_argument("--out", default="TUNE_artifact.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # -- 1. task + search -------------------------------------------------
    if args.smoke:
        task = tune.make_knot_task(n_train=4096, n_val=512, epochs=60,
                                   seed=args.seed)
        space = tune.DesignSpace(grid_size=(3, 5, 8),
                                 voltage_bits=(3, 4, 5),
                                 array_rows=(128,))
        cfg = tune.SearchConfig(budget=10, n_init=4, seed=args.seed)
    else:
        task = tune.make_knot_task(n_train=8192, n_val=1024, epochs=120,
                                   seed=args.seed)
        space = tune.DesignSpace()
        cfg = tune.SearchConfig(budget=32, n_init=8, seed=args.seed)
    hc = HardwareConstraints(max_area_mm2=0.02, max_energy_pj=300,
                             max_latency_ns=900)
    result = tune.pareto_search(task, space, constraints=hc, config=cfg)
    print(f"search: {result.n_evals} evals, {len(result.front)} Pareto "
          f"points (space {result.space_hash}, seed {result.seed})")
    if not result.front:
        print("ERROR: empty Pareto front", file=sys.stderr)
        return 1
    base = result.baseline
    print(f"baseline: acc={base.metrics['accuracy']:.3f} "
          f"energy={base.metrics['energy_pj']:.0f} pJ")
    for p in result.front:
        c, m = p.candidate, p.metrics
        print(f"  front: G={c.grid_size} K={c.order} vb={c.voltage_bits} "
              f"sam={int(c.use_sam)} -> acc={m['accuracy']:.3f} "
              f"energy={m['energy_pj']:.0f} pJ area={m['area_mm2']:.4f} mm^2")
    dom = result.dominating_baseline(on=("energy_pj", "accuracy"))
    print(f"{len(dom)} front points dominate the un-searched default on "
          "(energy, accuracy)")

    # -- 2. choose + deploy + tile-tune ----------------------------------
    chosen = tune.select_point(result.front)
    print(f"chosen: {chosen.candidate}")
    kspec, _, dep = tune.deploy_candidate(task, chosen.candidate)
    tile = tune.tune_tiles(dep, max_candidates=6 if args.smoke else 16,
                           seed=args.seed)
    print(f"tile tuner: mode={tile.mode}, {len(tile.trials)} trials, "
          f"plan source now: {'tuned' if tile.tuned else 'heuristic'}")
    x_probe = jax.random.uniform(jax.random.PRNGKey(args.seed + 1),
                                 (64, task.dims[0]), minval=-1.0, maxval=1.0)
    y_tuned = np.asarray(kan_network_deploy_apply(dep, x_probe))

    # -- 3. artifact round trip ------------------------------------------
    art = tune.build_tuning_artifact(search=result, chosen=chosen, tile=tile,
                                     task=task.name)
    tune.save_tuning_artifact(args.out, art)
    print(f"wrote {args.out}")

    runtime.reset_cache()  # cold runtime: the file is all we have
    loaded = tune.load_tuning_artifact(args.out)
    resolved = tune.apply_tuning_artifact(loaded)
    cand2 = resolved["candidate"]
    if cand2 != chosen.candidate:
        print("ERROR: reloaded candidate differs", file=sys.stderr)
        return 1
    if resolved["plan"] != tile.chosen_plan:
        print("ERROR: reloaded plan differs", file=sys.stderr)
        return 1
    _, _, dep2 = tune.deploy_candidate(task, cand2)
    y_reloaded = np.asarray(kan_network_deploy_apply(dep2, x_probe))
    if not np.array_equal(y_tuned, y_reloaded):
        print("ERROR: reloaded deployment is not bit-identical",
              file=sys.stderr)
        return 1
    print("artifact round trip OK: reloaded deployment is bit-identical")
    return 0


if __name__ == "__main__":
    sys.exit(main())
