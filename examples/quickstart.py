"""Quickstart: build a KAN, quantize it with ASP-KAN-HAQ, run all three
execution paths (float / quantized-LUT / Pallas kernel) and compare.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.asp_quant import ASPQuantSpec, quantize_input
from repro.core.kan_layer import (
    KANSpec, init_kan_network, kan_network_apply, quantize_kan_layer,
)
from repro.kernels.kan_spline.ops import kan_spline_from_qparams


def main():
    # the paper's edge KAN: 17 -> 1 -> 14, G=5 (KAN1 design point)
    kspec = KANSpec(dims=(17, 1, 14), grid_size=5, n_bits=8)
    spec = kspec.layer_spec()
    print(f"KAN {kspec.dims}, G={kspec.grid_size}, K={kspec.order}")
    print(f"ASP bit split: LD={spec.ld} -> global={spec.global_bits} bits "
          f"(knot interval), local={spec.ld} bits (intra-interval)")
    print(f"code range [0, {spec.num_codes - 1}] (eq. (6): G*2^LD <= 2^n)")

    key = jax.random.PRNGKey(0)
    params = init_kan_network(key, kspec)
    x = jax.random.uniform(key, (8, 17), minval=-1.0, maxval=1.0)

    # 1) float path (training path)
    y_float = kan_network_apply(params, x, kspec)

    # 2) ASP-quantized path (shared SH-LUT + banded matmul)
    qparams = [quantize_kan_layer(p, spec) for p in params]
    y_quant = kan_network_apply(None, x, kspec, quantized=True,
                                qparams_list=qparams)

    # 3) the Pallas TPU kernel (interpret mode on CPU), layer by layer
    h = x
    for qp in qparams:
        codes = quantize_input(h, spec)
        h = kan_spline_from_qparams(codes, qp, spec, interpret=True)
        if qp is not qparams[-1]:
            h = jnp.tanh(h)
    y_kernel = h

    # 4) the fused multi-layer pipeline: every layer in the Pallas kernel,
    #    inter-layer requantization fused, activations stay int codes
    y_fused = kan_network_apply(None, x, kspec, quantized=True,
                                qparams_list=qparams, backend="pallas",
                                interpret=True)

    print("\nfloat    ", y_float[0, :5])
    print("quantized", y_quant[0, :5])
    print("kernel   ", y_kernel[0, :5])
    print("fused    ", y_fused[0, :5])
    print("\nmax |float - quantized| =", float(jnp.abs(y_float - y_quant).max()))
    print("max |quantized - kernel| =", float(jnp.abs(y_quant - y_kernel).max()))
    print("max |quantized - fused|  =", float(jnp.abs(y_quant - y_fused).max()))
    e = quantize_kan_layer(params[0], spec)
    print(f"\nSH-LUT: {len(e['hemi'])} stored entries "
          f"(vs {(spec.order + 1) * spec.codes_per_interval} unfolded, "
          f"vs {(spec.num_basis) * 2**spec.n_bits} for per-B_i tables)")


if __name__ == "__main__":
    main()
