"""Process-wide metrics registry: counters, gauges, histograms.

One registry (:data:`REGISTRY`) absorbs every counter the stack already
kept in fragments — plan-cache hit/miss/trace counts, KV-pool block
accounting, scheduler admission counters, per-backend dispatch counts —
behind stable dotted names (``plan_cache.hits``, ``kv.blocks_in_use``,
``serve.ttft_s``, ``runtime.backend_dispatch{backend=...}``; the glossary
lives in ``docs/observability.md``).  Three instrument kinds:

  * :class:`Counter` — monotonically increasing (``inc``);
  * :class:`Gauge` — a point-in-time value (``set``);
  * :class:`Histogram` — observations bucketed into FIXED, deterministic
    edges chosen at creation (no dynamic rebinning — two runs of the same
    workload produce identical bucket vectors), plus running count/sum and
    min/max.

All three support label sets (``counter.labels(backend="pallas").inc()``);
each label combination is an independent series, exported as
``name{k=v,...}``.

**Default-off, zero-cost when off.**  The module-level :func:`enabled`
flag (set by :func:`enable` / :func:`disable`, seeded from the
``REPRO_OBS`` env var) gates every record path: a disabled instrument's
``inc``/``set``/``observe`` is one boolean check and a return, and the
serving hot paths additionally skip their obs blocks entirely.  Greedy
token streams are bit-identical with observability on or off — recording
never feeds back into execution.

Sources that already keep their own counters (the plan cache, the KV
pool) are pulled at *snapshot time* through **collectors** — callables
registered with :func:`MetricsRegistry.register_collector` that return
``{dotted_name: value}`` mappings — so the hot paths those counters live
on pay nothing extra.
"""

from __future__ import annotations

import bisect
import dataclasses
import os
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "DEFAULT_TIME_EDGES_S",
    "enable",
    "disable",
    "enabled",
]

ENV_OBS_VAR = "REPRO_OBS"

# Fixed latency bucket edges (seconds): 100us .. ~100s, x4 steps.  Chosen
# once, never rebinned — deterministic across runs and backends.
DEFAULT_TIME_EDGES_S = (
    0.0001, 0.0004, 0.0016, 0.0064, 0.0256, 0.1024, 0.4096, 1.6384,
    6.5536, 26.2144, 104.8576,
)

_ENABLED = os.environ.get(ENV_OBS_VAR, "").strip().lower() in (
    "1", "true", "on", "yes")


def enabled() -> bool:
    """Is observability recording on for this process?"""
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def format_series(name: str, labels: tuple) -> str:
    """``name`` or ``name{k=v,...}`` — the exported series identity."""
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class _Instrument:
    """Shared label-series bookkeeping for all three instrument kinds."""

    kind = "?"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict = {}           # label tuple -> series state
        self._lock = threading.Lock()

    def labels(self, **labels):
        """A bound view of this instrument for one label combination."""
        return _Bound(self, _label_key(labels))

    def _get(self, key: tuple):
        s = self._series.get(key)
        if s is None:
            with self._lock:
                s = self._series.setdefault(key, self._new_series())
        return s

    def series(self) -> dict:
        """Snapshot of every label series: label tuple -> exported value."""
        with self._lock:
            return {k: self._export(s) for k, s in self._series.items()}

    def reset(self) -> None:
        with self._lock:
            self._series.clear()


class _Bound:
    """One (instrument, label set) pair; forwards the record methods."""

    def __init__(self, inst, key):
        self._inst = inst
        self._key = key

    def inc(self, n=1):
        self._inst._inc(self._key, n)

    def set(self, v):
        self._inst._set(self._key, v)

    def observe(self, v):
        self._inst._observe(self._key, v)


class Counter(_Instrument):
    kind = "counter"

    def _new_series(self):
        return [0]

    def _export(self, s):
        return s[0]

    def _inc(self, key, n):
        if not _ENABLED:
            return
        self._get(key)[0] += n

    def inc(self, n=1, **labels):
        self._inc(_label_key(labels), n)


class Gauge(_Instrument):
    kind = "gauge"

    def _new_series(self):
        return [0.0]

    def _export(self, s):
        return s[0]

    def _set(self, key, v):
        if not _ENABLED:
            return
        self._get(key)[0] = v

    def set(self, v, **labels):
        self._set(_label_key(labels), v)


@dataclasses.dataclass
class _HistSeries:
    counts: list                 # len(edges) + 1 (the last is +Inf overflow)
    count: int = 0
    sum: float = 0.0
    min: float | None = None
    max: float | None = None


class Histogram(_Instrument):
    """Fixed-edge histogram: ``edges[i]`` is the inclusive upper bound of
    bucket i; observations past the last edge land in the +Inf bucket."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 edges: tuple = DEFAULT_TIME_EDGES_S):
        super().__init__(name, help)
        if list(edges) != sorted(edges) or len(set(edges)) != len(edges):
            raise ValueError(f"histogram edges must strictly increase: {edges}")
        self.edges = tuple(float(e) for e in edges)

    def _new_series(self):
        return _HistSeries(counts=[0] * (len(self.edges) + 1))

    def _export(self, s: _HistSeries) -> dict:
        return {
            "edges": list(self.edges),
            "counts": list(s.counts),
            "count": s.count,
            "sum": s.sum,
            "min": s.min,
            "max": s.max,
            "mean": (s.sum / s.count) if s.count else None,
        }

    def _observe(self, key, v):
        if not _ENABLED:
            return
        v = float(v)
        s = self._get(key)
        s.counts[bisect.bisect_left(self.edges, v)] += 1
        s.count += 1
        s.sum += v
        s.min = v if s.min is None else min(s.min, v)
        s.max = v if s.max is None else max(s.max, v)

    def observe(self, v, **labels):
        self._observe(_label_key(labels), v)


class MetricsRegistry:
    """Name -> instrument map + snapshot-time collectors.

    ``counter``/``gauge``/``histogram`` are get-or-create: the first call
    for a name fixes its kind (and a histogram's edges); later calls with
    the same name return the same instrument, and a kind mismatch raises —
    two subsystems can never silently split one metric name.
    """

    def __init__(self):
        self._instruments: dict = {}
        self._collectors: list = []
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name, help, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, help, **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}"
                )
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  edges: tuple = DEFAULT_TIME_EDGES_S) -> Histogram:
        return self._get_or_create(Histogram, name, help, edges=edges)

    def register_collector(self, fn) -> None:
        """``fn() -> {key: value}``, pulled at every snapshot.

        Keys are dotted names, or ``(name, ((label, value), ...))`` tuples
        for labeled series; values are numbers, exported as gauges.  A
        registered instrument with the same series identity wins the
        collision.  Unregister with :meth:`unregister_collector`.
        """
        with self._lock:
            self._collectors.append(fn)

    def unregister_collector(self, fn) -> None:
        with self._lock:
            if fn in self._collectors:
                self._collectors.remove(fn)

    def collect(self) -> list:
        """Every live series: ``(name, labels_tuple, kind, value)`` rows,
        sorted by series name — collector-sourced rows (exported as
        gauges) first, instrument series overriding on name collision."""
        rows: dict = {}
        with self._lock:
            instruments = list(self._instruments.values())
            collectors = list(self._collectors)
        for fn in collectors:
            for key, value in fn().items():
                name, labels = (key, ()) if isinstance(key, str) else (
                    key[0], tuple(key[1]))
                rows[(name, labels)] = (name, labels, "gauge", value)
        for inst in instruments:
            for labels, value in inst.series().items():
                rows[(inst.name, labels)] = (inst.name, labels, inst.kind,
                                             value)
        return [rows[k] for k in sorted(rows)]

    def snapshot(self) -> dict:
        """JSON-ready state: ``{"metrics": {series: {"kind", "value"}}}``
        with histogram values expanded to their bucket dicts; collector
        values merged in as gauges."""
        return {"metrics": {
            format_series(name, labels): {"kind": kind, "value": value}
            for name, labels, kind, value in self.collect()
        }}

    def reset(self, collectors: bool = False) -> None:
        """Zero every series (tests / process reuse).  Collectors survive by
        default — import-time registrations (e.g. the plan cache's) must
        keep feeding later snapshots; pass ``collectors=True`` to drop the
        per-instance ones too."""
        with self._lock:
            for inst in self._instruments.values():
                inst.reset()
            if collectors:
                self._collectors.clear()


# The process-wide registry every subsystem records into.
REGISTRY = MetricsRegistry()
