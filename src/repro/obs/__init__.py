"""Unified observability: metrics registry, request tracing, structured logs.

The serve/runtime stack grew counters in fragments — ``Scheduler.stats()``,
the plan cache's hit/miss/trace counts, ``ServeEngine.compile_stats()`` /
``kv_stats()``, ad-hoc ``log=`` lambdas.  This package gives them one
shared schema and one way out of the process:

  * :mod:`repro.obs.metrics` — a process-wide registry of counters /
    gauges / fixed-bucket histograms under stable dotted names
    (``plan_cache.hits``, ``kv.blocks_in_use``, ``serve.ttft_s``,
    ``runtime.backend_dispatch{backend=...}``), default-off and zero-cost
    when off; existing counter owners feed it through snapshot-time
    collectors.
  * :mod:`repro.obs.trace` — per-request span timelines recorded on the
    scheduler's injectable clock (deterministic under ``ManualClock``),
    exported as JSONL or Chrome-trace JSON, plus ``jax.profiler``
    annotation scopes for kernel dispatch sites.
  * :mod:`repro.obs.logging` — one leveled structured logger
    (``REPRO_LOG_LEVEL``) replacing the ad-hoc ``log=`` lambdas, with the
    bare-callable back-compat path preserved.
  * :mod:`repro.obs.exposition` — Prometheus text format, JSON snapshot,
    and a stdlib HTTP ``/metrics`` server.

Metric names, the span taxonomy and the exposition formats are documented
in ``docs/observability.md``.

    from repro import obs
    obs.enable()
    obs.REGISTRY.counter("serve.submitted").inc()
    obs.REGISTRY.histogram("serve.ttft_s").observe(0.042)
    print(obs.prometheus_text())
"""

from .logging import (
    ENV_LOG_LEVEL_VAR,
    LEVELS,
    Logger,
    as_logger,
    get_logger,
)
from .exposition import (
    dump_metrics,
    parse_prometheus_text,
    prometheus_text,
    start_metrics_server,
)
from .metrics import (
    DEFAULT_TIME_EDGES_S,
    ENV_OBS_VAR,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    disable,
    enable,
    enabled,
)
from .trace import (
    Span,
    Tracer,
    disable_profiler_annotations,
    enable_profiler_annotations,
    profile_scope,
    profiler_annotations_enabled,
)

__all__ = [
    "DEFAULT_TIME_EDGES_S",
    "ENV_LOG_LEVEL_VAR",
    "ENV_OBS_VAR",
    "LEVELS",
    "REGISTRY",
    "Counter",
    "Gauge",
    "Histogram",
    "Logger",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "as_logger",
    "disable",
    "disable_profiler_annotations",
    "dump_metrics",
    "enable",
    "enable_profiler_annotations",
    "enabled",
    "get_logger",
    "parse_prometheus_text",
    "profile_scope",
    "profiler_annotations_enabled",
    "prometheus_text",
    "start_metrics_server",
]
