"""Per-request span tracing: deterministic timelines over the serve stack.

A :class:`Tracer` records **spans** (named intervals with attributes and a
parent) and **events** (named instants) against an injectable clock — the
same clock the scheduler runs on, so a workload driven by
``scheduler.ManualClock`` produces *byte-identical* JSONL traces run to
run: span ids are sequence numbers, timestamps come from the manual
clock, and export order is record order.  The scheduler threads one span
tree per request through its lifecycle::

    request              (submit -> done/expired)
      queued             (submit -> admit | expiry)
      prefill            (admit -> first token; chunks= counts rounds)
      * first_token      (instant)
      decode             (first token -> done; tokens=)

Two export formats:

  * :meth:`Tracer.export_jsonl` — one JSON object per line, schema
    ``{"type": "span"|"event", "name", "id", "parent", "rid", "t0",
    "t1", "attrs"}`` (events carry ``t0`` only).  The CI obs-smoke step
    schema-checks this file.
  * :meth:`Tracer.export_chrome` — Chrome ``chrome://tracing`` / Perfetto
    JSON (complete ``"X"`` events, microsecond timestamps, one row per
    request id), so a served workload can be read as a timeline.

For on-device visibility, :func:`profile_scope` wraps host-side dispatch
sites (the scheduler's decode round, the executor's kernel dispatch) in
``jax.profiler.TraceAnnotation`` when profiling is enabled
(:func:`enable_profiler_annotations`), so kernel dispatches nest under
the serving spans in a ``jax.profiler`` trace viewer.  Off by default and
a no-op context manager when off.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import time

__all__ = [
    "Span",
    "Tracer",
    "profile_scope",
    "enable_profiler_annotations",
    "disable_profiler_annotations",
    "profiler_annotations_enabled",
]


@dataclasses.dataclass
class Span:
    """One named interval; ``end()`` via the owning tracer."""

    name: str
    id: int
    parent: int | None
    rid: int | None
    t0: float
    t1: float | None = None
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def open(self) -> bool:
        return self.t1 is None


class Tracer:
    """Append-only span/event recorder with deterministic ids and times.

    ``clock`` is any zero-arg callable returning seconds (the scheduler
    passes its own, so trace timestamps share the ``arrival_s`` timebase);
    default wall ``time.perf_counter`` rebased to 0 at construction.
    ``max_records`` bounds memory for long-lived servers: the oldest
    *closed* records are dropped once exceeded (export notes the drop).
    """

    def __init__(self, clock=None, max_records: int = 100_000):
        if clock is None:
            t0 = time.perf_counter()
            clock = lambda: time.perf_counter() - t0
        self._now = clock
        self.max_records = max_records
        self._records: list = []       # Span | event dicts, record order
        self._open = 0
        self._next_id = 0
        self.dropped = 0

    # -- recording --------------------------------------------------------

    def begin(self, name: str, parent: Span | None = None,
              rid: int | None = None, **attrs) -> Span:
        """Open a span; close it with :meth:`end` (spans here are not
        lexically scoped — a request span stays open across many
        scheduling rounds)."""
        span = Span(
            name=name, id=self._next_id,
            parent=None if parent is None else parent.id,
            rid=rid if rid is not None else (
                None if parent is None else parent.rid),
            t0=self._now(), attrs=dict(attrs),
        )
        self._next_id += 1
        self._records.append(span)
        self._open += 1
        return span

    def end(self, span: Span, **attrs) -> Span:
        if span.t1 is not None:
            raise ValueError(f"span {span.name}#{span.id} already ended")
        span.t1 = self._now()
        span.attrs.update(attrs)
        self._open -= 1
        self._trim()
        return span

    def event(self, name: str, parent: Span | None = None,
              rid: int | None = None, **attrs) -> None:
        """A named instant (exported with ``t0`` only)."""
        self._records.append({
            "name": name, "id": self._next_id,
            "parent": None if parent is None else parent.id,
            "rid": rid if rid is not None else (
                None if parent is None else parent.rid),
            "t0": self._now(), "attrs": dict(attrs),
        })
        self._next_id += 1
        self._trim()

    @contextlib.contextmanager
    def span(self, name: str, parent: Span | None = None,
             rid: int | None = None, **attrs):
        """Lexically-scoped convenience over begin/end."""
        s = self.begin(name, parent=parent, rid=rid, **attrs)
        try:
            yield s
        finally:
            self.end(s)

    def _trim(self) -> None:
        # drop oldest CLOSED records past the cap; open spans must survive
        # (their end() still mutates them in place)
        excess = len(self._records) - self.max_records
        if excess <= 0:
            return
        keep = []
        for r in self._records:
            if excess > 0 and not (isinstance(r, Span) and r.open):
                excess -= 1
                self.dropped += 1
            else:
                keep.append(r)
        self._records = keep

    # -- export -----------------------------------------------------------

    def records(self) -> list:
        """Every record as a JSON-ready dict, in record order."""
        out = []
        for r in self._records:
            if isinstance(r, Span):
                out.append({
                    "type": "span", "name": r.name, "id": r.id,
                    "parent": r.parent, "rid": r.rid,
                    "t0": round(r.t0, 9),
                    "t1": None if r.t1 is None else round(r.t1, 9),
                    "attrs": r.attrs,
                })
            else:
                out.append({
                    "type": "event", "name": r["name"], "id": r["id"],
                    "parent": r["parent"], "rid": r["rid"],
                    "t0": round(r["t0"], 9), "attrs": r["attrs"],
                })
        return out

    def skeleton(self) -> list:
        """The payload-free span tree: (type, name, id, parent, rid, t0, t1)
        tuples.  The trace-determinism acceptance compares THIS across
        backends — attrs may legitimately differ (e.g. ``backend=``)."""
        return [
            (d["type"], d["name"], d["id"], d["parent"], d["rid"],
             d["t0"], d.get("t1"))
            for d in self.records()
        ]

    def export_jsonl(self, path) -> None:
        """One compact JSON object per line, record order; deterministic
        byte-for-byte for a deterministic-clock run."""
        with open(path, "w") as f:
            for d in self.records():
                f.write(json.dumps(d, sort_keys=True,
                                   separators=(",", ":")) + "\n")
            if self.dropped:
                f.write(json.dumps(
                    {"type": "meta", "dropped_records": self.dropped},
                    sort_keys=True, separators=(",", ":")) + "\n")

    def export_chrome(self, path) -> None:
        """Chrome trace-event JSON: ``ph:"X"`` complete events in
        microseconds, ``tid`` = request id (-1 for global spans) so each
        request reads as one timeline row."""
        events = []
        for d in self.records():
            tid = -1 if d["rid"] is None else d["rid"]
            base = {"name": d["name"], "pid": 0, "tid": tid,
                    "ts": d["t0"] * 1e6, "args": d["attrs"]}
            if d["type"] == "span":
                t1 = d["t1"] if d["t1"] is not None else d["t0"]
                events.append({**base, "ph": "X",
                               "dur": (t1 - d["t0"]) * 1e6})
            else:
                events.append({**base, "ph": "i", "s": "t"})
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)

    def clear(self) -> None:
        self._records = []
        self._open = 0
        self._next_id = 0
        self.dropped = 0


# -- jax.profiler annotation hooks ------------------------------------------

_PROFILER_ANNOTATIONS = False


def enable_profiler_annotations() -> None:
    """Turn host-side ``jax.profiler.TraceAnnotation`` wrapping on for the
    instrumented dispatch sites (scheduler rounds, executor dispatch)."""
    global _PROFILER_ANNOTATIONS
    _PROFILER_ANNOTATIONS = True


def disable_profiler_annotations() -> None:
    global _PROFILER_ANNOTATIONS
    _PROFILER_ANNOTATIONS = False


def profiler_annotations_enabled() -> bool:
    return _PROFILER_ANNOTATIONS


def profile_scope(name: str, **kwargs):
    """``jax.profiler.TraceAnnotation(name)`` when annotations are enabled
    (and jax is importable); a free null context otherwise — safe to wrap
    hot dispatch sites unconditionally."""
    if not _PROFILER_ANNOTATIONS:
        return contextlib.nullcontext()
    try:
        from jax.profiler import TraceAnnotation
    except Exception:  # pragma: no cover - jax always present in this repo
        return contextlib.nullcontext()
    return TraceAnnotation(name, **kwargs)
