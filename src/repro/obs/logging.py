"""Structured, leveled logging for the serve/runtime stack.

One logger replaces the ad-hoc ``log=`` lambdas that grew across the
scheduler and ``launch.serve``: every record is one line of
``level event key=value ...`` text built from structured fields, filtered
by a level threshold (``REPRO_LOG_LEVEL`` env var, or per-logger
``level=``), and written through a pluggable sink.

Back-compat is explicit: :func:`as_logger` turns the legacy bare-callable
``log=`` argument (e.g. ``log=print``) into a :class:`Logger` whose sink
is that callable and whose threshold is DEBUG — a caller who passed a
lambda keeps receiving every message, formatted exactly as the f-strings
it used to get.  ``Logger.__call__`` aliases :meth:`Logger.info`, so code
holding a logger can still invoke it like the old lambda.

    from repro import obs
    log = obs.get_logger("serve")
    log.info("request done", rid=3, tokens=17, latency_s=0.042)
    # -> "serve: request done rid=3 tokens=17 latency_s=0.042"
"""

from __future__ import annotations

import os
import sys

__all__ = ["Logger", "get_logger", "as_logger", "LEVELS", "ENV_LOG_LEVEL_VAR"]

ENV_LOG_LEVEL_VAR = "REPRO_LOG_LEVEL"

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}


def _env_level() -> int:
    raw = os.environ.get(ENV_LOG_LEVEL_VAR, "").strip().lower()
    return LEVELS.get(raw, LEVELS["info"])


def _fmt_value(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    s = str(v)
    return repr(s) if " " in s else s


class Logger:
    """Leveled structured logger writing one-line records to a sink.

    ``sink`` is any ``callable(str)`` (default: stdout write);
    ``level=None`` reads ``REPRO_LOG_LEVEL`` at each record, so the env
    var takes effect without plumbing.
    """

    def __init__(self, name: str = "", sink=None, level: str | None = None):
        self.name = name
        self.sink = sink if sink is not None else (
            lambda line: print(line, file=sys.stdout, flush=True))
        self._level = None if level is None else LEVELS[level]

    def threshold(self) -> int:
        return self._level if self._level is not None else _env_level()

    def enabled_for(self, level: str) -> bool:
        return LEVELS[level] >= self.threshold()

    def log(self, level: str, event: str, **fields) -> None:
        if LEVELS[level] < self.threshold():
            return
        parts = [event] + [f"{k}={_fmt_value(v)}" for k, v in fields.items()]
        prefix = f"{self.name}: " if self.name else ""
        lvl = "" if level == "info" else f"[{level}] "
        self.sink(f"{prefix}{lvl}{' '.join(parts)}")

    def debug(self, event: str, **fields) -> None:
        self.log("debug", event, **fields)

    def info(self, event: str, **fields) -> None:
        self.log("info", event, **fields)

    def warning(self, event: str, **fields) -> None:
        self.log("warning", event, **fields)

    def error(self, event: str, **fields) -> None:
        self.log("error", event, **fields)

    # the legacy ``log=`` lambdas were called directly; keep that shape
    __call__ = info


_LOGGERS: dict = {}


def get_logger(name: str = "") -> Logger:
    """Process-wide named logger (stdout sink, env-var threshold)."""
    lg = _LOGGERS.get(name)
    if lg is None:
        lg = _LOGGERS[name] = Logger(name)
    return lg


def as_logger(log, name: str = "") -> Logger:
    """Normalize a ``log=`` argument to a :class:`Logger`.

    ``None`` -> the named process logger; a :class:`Logger` -> itself; any
    other callable -> the bare-lambda back-compat path: a DEBUG-threshold
    logger sinking every formatted line into the callable (the behavior
    callers of ``Scheduler(log=print)`` always had).
    """
    if log is None:
        return get_logger(name)
    if isinstance(log, Logger):
        return log
    if callable(log):
        return Logger(name="", sink=log, level="debug")
    raise TypeError(f"log must be None, a Logger or a callable; got {log!r}")
