"""Metrics exposition: Prometheus text format, JSON snapshot, HTTP server.

Three ways out of the process for :mod:`repro.obs.metrics` state:

  * :func:`prometheus_text` — the Prometheus text exposition format
    (``# TYPE`` headers, ``name{label="v"} value`` samples, histograms as
    cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count``).
    Dotted metric names map to underscores (``plan_cache.hits`` ->
    ``plan_cache_hits``); the dotted form stays canonical everywhere else.
  * :func:`dump_metrics` — write a file; ``.json`` suffix gets the JSON
    snapshot, anything else the Prometheus text.
  * :func:`start_metrics_server` — a stdlib ``http.server`` on a daemon
    thread serving ``/metrics`` (Prometheus text) and ``/metrics.json``
    (JSON snapshot), for scraping a live server
    (``launch.serve --metrics-port``).

All three read through :meth:`MetricsRegistry.collect`, so collector-fed
sources (plan cache, KV pool) are pulled fresh at exposition time.
"""

from __future__ import annotations

import json
import re
import threading

from .metrics import REGISTRY

__all__ = [
    "prometheus_text",
    "parse_prometheus_text",
    "dump_metrics",
    "start_metrics_server",
]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _prom_labels(labels: tuple, extra: tuple = ()) -> str:
    items = tuple(labels) + tuple(extra)
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


def _prom_num(v) -> str:
    if v is None:
        return "NaN"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def prometheus_text(registry=None) -> str:
    """The registry's series in Prometheus text exposition format."""
    registry = REGISTRY if registry is None else registry
    lines, typed = [], set()
    for name, labels, kind, value in registry.collect():
        pname = _prom_name(name)
        if kind == "histogram":
            if pname not in typed:
                typed.add(pname)
                lines.append(f"# TYPE {pname} histogram")
            cum = 0
            for edge, n in zip(value["edges"], value["counts"]):
                cum += n
                lines.append(
                    f"{pname}_bucket"
                    f"{_prom_labels(labels, (('le', _prom_num(edge)),))}"
                    f" {cum}")
            lines.append(
                f"{pname}_bucket{_prom_labels(labels, (('le', '+Inf'),))}"
                f" {value['count']}")
            lines.append(
                f"{pname}_sum{_prom_labels(labels)} {_prom_num(value['sum'])}")
            lines.append(
                f"{pname}_count{_prom_labels(labels)} {value['count']}")
        else:
            if pname not in typed:
                typed.add(pname)
                lines.append(f"# TYPE {pname} {kind}")
            lines.append(
                f"{pname}{_prom_labels(labels)} {_prom_num(value)}")
    return "\n".join(lines) + ("\n" if lines else "")


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"           # metric name
    r"(\{[^}]*\})?"                          # optional label set
    r"\s+(NaN|[+-]?(?:\d+\.?\d*(?:[eE][+-]?\d+)?|\.\d+|\+?Inf))$"
)


def parse_prometheus_text(text: str) -> dict:
    """Strict parse of the text exposition; raises ValueError on any line
    that is neither a comment nor a valid sample.  Returns
    ``{series_string: float}`` — the CI obs-smoke validation path."""
    out: dict = {}
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {i}: not a valid prometheus sample: "
                             f"{line!r}")
        name, labels, num = m.groups()
        out[name + (labels or "")] = float(num)
    return out


def dump_metrics(path, registry=None) -> None:
    """Write the registry to ``path``: ``*.json`` -> JSON snapshot,
    anything else -> Prometheus text."""
    registry = REGISTRY if registry is None else registry
    if str(path).endswith(".json"):
        with open(path, "w") as f:
            json.dump(registry.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")
    else:
        with open(path, "w") as f:
            f.write(prometheus_text(registry))


def start_metrics_server(port: int, registry=None, host: str = "127.0.0.1"):
    """Serve ``/metrics`` + ``/metrics.json`` on a daemon thread.

    Returns the ``http.server.HTTPServer`` (its ``server_port`` reports
    the bound port — pass ``port=0`` for an ephemeral one; call
    ``shutdown()`` to stop).
    """
    import http.server

    registry = REGISTRY if registry is None else registry

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 - http.server API
            if self.path.split("?")[0] == "/metrics":
                body = prometheus_text(registry).encode()
                ctype = "text/plain; version=0.0.4"
            elif self.path.split("?")[0] == "/metrics.json":
                body = json.dumps(registry.snapshot(),
                                  sort_keys=True).encode()
                ctype = "application/json"
            else:
                self.send_response(404)
                self.end_headers()
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # silence per-request stderr lines
            pass

    server = http.server.HTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name=f"obs-metrics:{server.server_port}")
    thread.start()
    return server
