"""Batched serving engine: slot-based continuous batching.

A fixed pool of B decode slots; each slot holds one active request.  New
requests are prefillied into a free slot (per-slot cache splice), decode
advances ALL active slots with one compiled step, finished slots (EOS or
max_tokens) are immediately refilled from the queue — the standard
continuous-batching loop (vLLM-style, without paging) on top of
models.model.{prefill, decode_step}.

On CPU/smoke configs this is a functional demo; the same engine drives the
decode_32k serve_step that the dry-run lowers at production shapes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list                 # token ids
    max_new_tokens: int = 32
    eos_id: int = 2
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 kan_deploy: bool = False):
        if kan_deploy:
            # Execute every KAN-FFN block on the paper's quantized datapath:
            # int8 c' + SH-LUT through the fused kan_spline Pallas pipeline
            # (decode AND prefill steps — the whole serving hot path).
            if cfg.ffn_kind != "kan":
                raise ValueError(
                    "kan_deploy requires a KAN-FFN config (cfg.kan_variant())"
                )
            from ..core.kan_ffn_deploy import quantize_kan_ffn_params_tree

            params = quantize_kan_ffn_params_tree(params, cfg)
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.cache = M.init_cache(params, cfg, slots, max_len)
        self.pos = np.zeros(slots, np.int32)
        self.active: list[Request | None] = [None] * slots
        self._t0 = {}

        cfg_ = cfg

        @jax.jit
        def _decode(params, cache, token, pos):
            return M.decode_step(params, cache, token, pos, cfg_)

        self._decode = _decode

        @jax.jit
        def _prefill_one(params, tokens):
            return M.prefill(params, {"tokens": tokens}, cfg_, max_len=max_len)

        self._prefill_one = _prefill_one

    # -- slot management ------------------------------------------------

    def _free_slot(self):
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _admit(self, req: Request):
        slot = self._free_slot()
        assert slot is not None
        # prefill the request alone (B=1), splice its cache into the pool
        tokens = jnp.asarray([req.prompt], jnp.int32)
        logits, cache1 = self._prefill_one(self.params, tokens)
        self.cache = jax.tree.map(
            lambda pool, one: pool.at[:, slot].set(one[:, 0]), self.cache, cache1
        )
        self.pos[slot] = len(req.prompt)
        first = int(jnp.argmax(logits[0]))
        req.output.append(first)
        self.active[slot] = req
        self._t0[req.rid] = time.perf_counter()

    # -- main loop --------------------------------------------------------

    def run(self, requests: list, log: Callable = lambda *_: None):
        queue = list(requests)
        results = []
        while queue or any(r is not None for r in self.active):
            while queue and self._free_slot() is not None:
                self._admit(queue.pop(0))
                log(f"admitted request; {len(queue)} queued")
            # one decode step for the whole pool
            tokens = np.zeros(self.slots, np.int32)
            for i, r in enumerate(self.active):
                if r is not None:
                    tokens[i] = r.output[-1]
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(self.pos),
            )
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for i, r in enumerate(self.active):
                if r is None:
                    continue
                self.pos[i] += 1
                tok = int(nxt[i])
                r.output.append(tok)
                if (tok == r.eos_id or len(r.output) >= r.max_new_tokens
                        or self.pos[i] >= self.max_len - 1):
                    r.done = True
                    r.latency_s = time.perf_counter() - self._t0[r.rid]
                    results.append(r)
                    self.active[i] = None
                    log(f"request {r.rid} done ({len(r.output)} tokens, "
                        f"{r.latency_s:.2f}s)")
        return results
