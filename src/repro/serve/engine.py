"""Batched serving engine: slot-based continuous batching.

A fixed pool of B decode slots; each slot holds one active request.  New
requests are prefilled into a free slot (per-slot cache splice), decode
advances ALL active slots with one compiled step, finished slots (EOS or
max_tokens) are immediately refilled from the queue — the standard
continuous-batching loop (vLLM-style, without paging) on top of
models.model.{prefill, decode_step}.

The engine owns the *state* (params, slot pool, KV cache, compiled steps);
the *loop* lives in :mod:`repro.serve.scheduler`, which adds arrival times,
admission/backpressure, deadlines, per-token streaming callbacks, seeded
sampling and TTFT/throughput metrics on top of the same internals.
``run()`` is kept as the thin synchronous driver over that scheduler and
decodes exactly the tokens the pre-scheduler loop did.

Compile behavior: decode compiles once; prefill pads prompts to
power-of-two length buckets so a mixed-length request stream compiles
O(log L) variants instead of one per distinct prompt length.  Padding lives
at the END of the prompt (causal attention means real positions never see
it), is zeroed out of the cache at splice time, and first-token logits are
read at the true last-token index — so bucketed and exact prefill emit the
same tokens.  Bucketing is enabled automatically for pure global-attention
decoders; recurrent/SSM/sliding-window stacks fall back to exact-length
prefill (their states integrate the pad tokens).

When ``kan_deploy=True`` every KAN-FFN block executes through the
``repro.runtime`` registry (``kan_backend`` > ``REPRO_KAN_BACKEND`` >
"pallas"), sharing the runtime's plan/compile cache across prefill and
decode.

Attention routes through the runtime attention registry the same way:
``attn_backend`` ("ref" = chunked XLA, "flash" = fused Pallas
flash-attention) resolves at engine build (explicit arg >
``REPRO_ATTN_BACKEND`` > flash-on-TPU/ref-elsewhere) and rides the
compiled prefill/decode closures as a static jit argument — the backend is
part of the compile key, so two engines with different attention backends
never share a stale trace.  With ``kan_deploy=True`` and
``attn_backend="flash"`` every FLOP-heavy op of the decode step (attention
AND both KAN-FFN halves) executes as a fused Pallas kernel.

With ``mesh=`` the engine serves distributed: params are placed by the
role-based sharding rules, the slot pool / KV cache shard their slot dim
on "data" (decode advances all slots data-parallel), and every prefill /
decode step runs under ``runtime.use_mesh``, so the KAN-FFN blocks execute
on the mesh-sharded fused pipeline (batch on "data", output channels on
"model").  A single-device mesh serves the same tokens as no mesh at all.

On CPU/smoke configs this is a functional demo; the same engine drives the
decode_32k serve_step that the dry-run lowers at production shapes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M
from .. import runtime

__all__ = ["Request", "ServeEngine", "prefill_bucketing_supported"]


def prefill_bucketing_supported(cfg: ModelConfig) -> bool:
    """Right-padded prefill is exact only when no layer state integrates the
    pad tokens: pure global-attention decoders qualify (causal masking +
    masked cache splice make padding invisible); sliding-window caches,
    RG-LRU/SSD states, and encoder/VLM prefixes do not."""
    return (
        cfg.encoder_layers == 0
        and cfg.family not in ("audio", "vlm")
        and all(k == "global" for k in cfg.layer_kinds)
    )


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list                 # token ids
    max_new_tokens: int = 32
    eos_id: int = 2
    # scheduling inputs (consumed by repro.serve.scheduler; the defaults
    # reproduce the classic run() semantics — arrive immediately, never
    # expire, greedy decode — so pre-scheduler call sites work unchanged):
    arrival_s: float = 0.0       # offset from scheduler start; 0 = now
    deadline_s: float | None = None  # max queued seconds before expiry
    sampling: Any = None         # SamplingParams, or None for greedy
    # filled by the engine/scheduler:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "pending"      # pending -> queued -> running -> done|expired
    latency_s: float = 0.0       # admission -> last token
    ttft_s: float = 0.0          # arrival -> first token


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 kan_deploy: bool = False, kan_backend: str | None = None,
                 attn_backend: str | None = None,
                 prefill_buckets: bool | None = None, mesh=None):
        if kan_deploy:
            # Execute every KAN-FFN block on the paper's quantized datapath:
            # int8 c' + SH-LUT through the repro.runtime executor registry
            # (decode AND prefill steps — the whole serving hot path).
            if cfg.ffn_kind != "kan":
                raise ValueError(
                    "kan_deploy requires a KAN-FFN config (cfg.kan_variant())"
                )
            # validate eagerly so a typo'd backend fails at engine build,
            # not at first admit
            runtime.resolve_backend(kan_backend)
            from ..core.kan_ffn_deploy import quantize_kan_ffn_params_tree

            params = quantize_kan_ffn_params_tree(params, cfg)
        self.mesh = mesh
        if mesh is not None:
            # Distributed serving: params follow the role-based rules
            # (attention/FFN weights on "model" where the axis divides, the
            # quantized KAN bundles ride replicated — the runtime's
            # shard_map distributes their padded pipeline form at execution)
            # and the slot pool / KV cache shard their slot dim on "data",
            # so every decode step advances the pool data-parallel.
            from ..dist.sharding import cache_pspecs, param_pspecs, to_shardings

            params = jax.device_put(
                params, to_shardings(param_pspecs(params, mesh), mesh)
            )
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.kan_backend = kan_backend if kan_deploy else None
        # Attention backend ("ref" XLA / "flash" fused Pallas): resolved and
        # validated EAGERLY — a typo fails at engine build, and the resolved
        # name is baked into the compiled prefill/decode closures as a
        # static jit argument, so switching backends retraces instead of
        # silently reusing the other backend's step (plan-cache keying).
        self.attn_backend = runtime.resolve_attn_backend(attn_backend)
        if prefill_buckets is None:
            prefill_buckets = prefill_bucketing_supported(cfg)
        self.prefill_buckets = prefill_buckets and prefill_bucketing_supported(cfg)
        self.cache = M.init_cache(params, cfg, slots, max_len)
        self._slots_sharded = False
        if mesh is not None:
            from jax.sharding import PartitionSpec

            cspecs = cache_pspecs(self.cache, mesh, slots)
            # report what cache_pspecs actually decided (the CLI banner
            # echoes this) instead of re-deriving its divisibility rule
            self._slots_sharded = any(
                "data" in tuple(s) for s in jax.tree.leaves(
                    cspecs, is_leaf=lambda x: isinstance(x, PartitionSpec)
                )
            )
            self.cache = jax.device_put(
                self.cache, to_shardings(cspecs, mesh)
            )
        self.pos = np.zeros(slots, np.int32)
        self.active: list[Request | None] = [None] * slots
        self.prefill_traces = 0
        self.decode_traces = 0

        cfg_ = cfg
        eng = self

        @functools.partial(jax.jit, static_argnames=("attn_backend",))
        def _decode(params, cache, token, pos, attn_backend):
            eng.decode_traces += 1  # python body runs only while tracing
            with runtime.use_attn_backend(attn_backend):
                return M.decode_step(params, cache, token, pos, cfg_)

        self._decode = functools.partial(_decode,
                                         attn_backend=self.attn_backend)

        @functools.partial(jax.jit, static_argnames=("attn_backend",))
        def _prefill_one(params, tokens, last_index, attn_backend):
            eng.prefill_traces += 1
            with runtime.use_attn_backend(attn_backend):
                return M.prefill(params, {"tokens": tokens}, cfg_,
                                 max_len=max_len, last_index=last_index)

        self._prefill_one = functools.partial(
            _prefill_one, attn_backend=self.attn_backend)

    # -- slot management ------------------------------------------------

    def _free_slot(self):
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _padded_prompt(self, prompt: list) -> list:
        """Right-pad to the power-of-two length bucket (token 0 as filler)."""
        if not self.prefill_buckets:
            return list(prompt)
        lb = runtime.bucket_batch(len(prompt))
        if lb > self.max_len - 1:
            return list(prompt)
        return list(prompt) + [0] * (lb - len(prompt))

    def _admit(self, req: Request):
        """Prefill ``req`` into a free slot and greedily pick its first token.

        The scheduler calls :meth:`_prefill_slot` directly (it owns token
        selection — sampling — and metrics); this wrapper keeps the classic
        greedy admission for direct engine use.
        """
        slot = self._free_slot()
        if slot is None:
            raise RuntimeError(
                f"ServeEngine._admit: no free slot for request {req.rid} "
                f"(all {self.slots} busy); check _free_slot() before admitting"
            )
        logits = self._prefill_slot(slot, req)
        req.output.append(int(np.argmax(logits)))

    def _prefill_slot(self, slot: int, req: Request) -> np.ndarray:
        """B=1 prefill of ``req`` into pool ``slot``; returns the (V,)
        first-token logits.  Splices the prompt's cache into the pool and
        activates the slot — everything about admission EXCEPT choosing the
        first token, which the caller does (greedy in ``_admit``, sampling
        and timing in the scheduler)."""
        plen = len(req.prompt)
        # prefill the request alone (B=1), splice its cache into the pool
        tokens = jnp.asarray([self._padded_prompt(req.prompt)], jnp.int32)
        with runtime.use_backend(self.kan_backend), runtime.use_mesh(self.mesh):
            logits, cache1 = self._prefill_one(
                self.params, tokens, jnp.asarray([plen - 1], jnp.int32)
            )
        # mask the padding in the cache splice: KV written past the real
        # prompt (pad tokens) is zeroed so no stale state enters the pool.
        tmask = jnp.arange(self.max_len) < plen

        def splice(pool, one):
            one = one[:, 0]                      # (repeats, T, H, D)
            if (self.prefill_buckets and one.ndim >= 2
                    and one.shape[1] == self.max_len):
                one = jnp.where(
                    tmask.reshape((1, -1) + (1,) * (one.ndim - 2)), one, 0
                )
            return pool.at[:, slot].set(one)

        self.cache = jax.tree.map(splice, self.cache, cache1)
        self.pos[slot] = plen
        self.active[slot] = req
        return np.asarray(logits[0])

    # -- main loop --------------------------------------------------------

    def run(self, requests: list, log: Callable = lambda *_: None):
        """Serve a batch synchronously; returns requests in completion order.

        Thin driver over :class:`repro.serve.scheduler.Scheduler`: submit
        everything up front (default ``arrival_s=0`` — all available
        immediately), run the event loop to idle.  FIFO admission into free
        slots + one pooled decode step per round is exactly the
        pre-scheduler loop, so greedy token streams are bit-identical to
        it; per-request deadlines/sampling fields are honored if callers
        set them.  Use the scheduler directly for streaming callbacks,
        backpressure and metrics.
        """
        from .scheduler import Scheduler

        sched = Scheduler(self, log=log)
        for req in requests:
            sched.submit(req)
        return sched.run_until_idle()

    def compile_stats(self) -> dict:
        """Engine-level trace counts + the runtime plan-cache counters."""
        return {
            "prefill_traces": self.prefill_traces,
            "decode_traces": self.decode_traces,
            "plan_cache": runtime.cache_stats(),
            "mesh": self.mesh_layout(),
            "attn_backend": self.attn_backend,
        }

    def mesh_layout(self) -> dict | None:
        """The serving mesh layout (axes x sizes + device count + whether
        the slot pool actually sharded on "data"), or None."""
        if self.mesh is None:
            return None
        return {
            "axes": list(self.mesh.axis_names),
            "shape": [int(s) for s in self.mesh.devices.shape],
            "devices": int(self.mesh.devices.size),
            "slots_sharded": self._slots_sharded,
        }

    def kan_plan_source(self) -> str | None:
        """Where the KAN-FFN pipeline geometry comes from.

        "tuned" when a ``repro.tune`` tile plan is registered for this
        engine's FFN geometry (e.g. loaded from a ``--tuned-config``
        artifact), "heuristic" for the built-in block-size heuristic, None
        when the engine is not serving a KAN-FFN deployment.
        """
        if self.cfg.ffn_kind != "kan":
            return None
        from ..models.layers import kan_ffn_hidden, kan_ffn_spec

        spec = kan_ffn_spec(self.cfg)
        d = self.cfg.d_model
        ov = runtime.PLAN_CACHE.get_tile_overrides(
            (d, kan_ffn_hidden(self.cfg), d), (spec, spec), True
        )
        return "tuned" if ov is not None else "heuristic"
