"""Batched serving engine: slot-based continuous batching.

A fixed pool of B decode slots; each slot holds one active request.  New
requests are prefilled into a free slot (per-slot cache splice), decode
advances ALL active slots with one compiled step, finished slots (EOS or
max_tokens) are immediately refilled from the queue — the standard
continuous-batching loop (vLLM-style, without paging) on top of
models.model.{prefill, decode_step}.

The engine owns the *state* (params, slot pool, KV cache, compiled steps);
the *loop* lives in :mod:`repro.serve.scheduler`, which adds arrival times,
admission/backpressure, deadlines, per-token streaming callbacks, seeded
sampling and TTFT/throughput metrics on top of the same internals.
``run()`` is kept as the thin synchronous driver over that scheduler and
decodes exactly the tokens the pre-scheduler loop did.

Compile behavior: decode compiles once; prefill pads prompts to
power-of-two length buckets so a mixed-length request stream compiles
O(log L) variants instead of one per distinct prompt length.  Padding lives
at the END of the prompt (causal attention means real positions never see
it), is zeroed out of the cache at splice time, and first-token logits are
read at the true last-token index — so bucketed and exact prefill emit the
same tokens.  Bucketing is enabled automatically for pure global-attention
decoders; recurrent/SSM/sliding-window stacks fall back to exact-length
prefill (their states integrate the pad tokens).

When ``kan_deploy=True`` every KAN-FFN block executes through the
``repro.runtime`` registry (``kan_backend`` > ``REPRO_KAN_BACKEND`` >
"pallas"), sharing the runtime's plan/compile cache across prefill and
decode.

Attention routes through the runtime attention registry the same way:
``attn_backend`` ("ref" = chunked XLA, "flash" = fused Pallas
flash-attention) resolves at engine build (explicit arg >
``REPRO_ATTN_BACKEND`` > flash-on-TPU/ref-elsewhere) and rides the
compiled prefill/decode closures as a static jit argument — the backend is
part of the compile key, so two engines with different attention backends
never share a stale trace.  With ``kan_deploy=True`` and
``attn_backend="flash"`` every FLOP-heavy op of the decode step (attention
AND both KAN-FFN halves) executes as a fused Pallas kernel.

With ``kv_block_size=`` the per-slot contiguous KV slab is replaced by a
PAGED pool: KV storage is cut into fixed-size blocks (a multiple of the
flash kernel's 8-row KV tile) handed out by a free-list allocator
(:mod:`repro.serve.kvpool`), each slot addresses its tokens through a
block table, and a hash-keyed prefix cache lets requests sharing a prompt
prefix splice the cached blocks in copy-free instead of re-prefilling.
``prefill_chunk=`` additionally stages long prompts: the scheduler
advances one chunk per round, interleaved with pooled decode, so one long
prompt can't stall TTFT for the pool.  Greedy token streams are
bit-identical to the contiguous path: the paged decode step gathers the
block table into exactly the contiguous cache's (B, max_len, ...) view,
and masked softmax lanes contribute exact zeros regardless of stale block
contents (see ``layers.attention_decode``).

With ``mesh=`` the engine serves distributed: params are placed by the
role-based sharding rules, the slot pool / KV cache shard their slot dim
on "data" — the paged pool shards its num_blocks dim there instead — and
every prefill / decode step runs under ``runtime.use_mesh``, so the
KAN-FFN blocks execute on the mesh-sharded fused pipeline (batch on
"data", output channels on "model").  A single-device mesh serves the
same tokens as no mesh at all.

On CPU/smoke configs this is a functional demo; the same engine drives the
decode_32k serve_step that the dry-run lowers at production shapes.
"""

from __future__ import annotations

import bisect
import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M
from .. import runtime
from ..obs.trace import profile_scope
from .kvpool import KVBlockPool

__all__ = ["Request", "ServeEngine", "prefill_bucketing_supported",
           "paged_kv_supported"]


def prefill_bucketing_supported(cfg: ModelConfig) -> bool:
    """Right-padded prefill is exact only when no layer state integrates the
    pad tokens: pure global-attention decoders qualify (causal masking +
    masked cache splice make padding invisible); sliding-window caches,
    RG-LRU/SSD states, and encoder/VLM prefixes do not."""
    return (
        cfg.encoder_layers == 0
        and cfg.family not in ("audio", "vlm")
        and all(k == "global" for k in cfg.layer_kinds)
    )


def paged_kv_supported(cfg: ModelConfig) -> bool:
    """Paged KV needs every layer's decode state to be a block-structured
    KV cache — the same pure global-attention decoder predicate as prefill
    bucketing (rolling-window / recurrent / encoder state has no pages)."""
    return prefill_bucketing_supported(cfg)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list                 # token ids
    max_new_tokens: int = 32
    eos_id: int = 2
    # scheduling inputs (consumed by repro.serve.scheduler; the defaults
    # reproduce the classic run() semantics — arrive immediately, never
    # expire, greedy decode — so pre-scheduler call sites work unchanged):
    arrival_s: float = 0.0       # offset from scheduler start; 0 = now
    deadline_s: float | None = None  # max queued seconds before expiry
    sampling: Any = None         # SamplingParams, or None for greedy
    # filled by the engine/scheduler:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "pending"      # pending -> queued -> running -> done|expired
    latency_s: float = 0.0       # admission -> last token
    ttft_s: float = 0.0          # arrival -> first token


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 kan_deploy: bool = False, kan_backend: str | None = None,
                 attn_backend: str | None = None,
                 prefill_buckets: bool | None = None, mesh=None,
                 kv_block_size: int | None = None,
                 kv_blocks: int | None = None, prefix_cache: bool = True,
                 prefill_chunk: int | None = None,
                 spec_decode: int = 0, draft_spec=None):
        # the drafter refits from the FLOAT weights; capture them before the
        # kan_deploy quantization below swaps the tree for int8 + SH-LUT
        float_params = params
        if kan_deploy:
            # Execute every KAN-FFN block on the paper's quantized datapath:
            # int8 c' + SH-LUT through the repro.runtime executor registry
            # (decode AND prefill steps — the whole serving hot path).
            if cfg.ffn_kind != "kan":
                raise ValueError(
                    "kan_deploy requires a KAN-FFN config (cfg.kan_variant())"
                )
            # validate eagerly so a typo'd backend fails at engine build,
            # not at first admit
            runtime.resolve_backend(kan_backend)
            from ..core.kan_ffn_deploy import quantize_kan_ffn_params_tree

            params = quantize_kan_ffn_params_tree(params, cfg)
        self.mesh = mesh
        if mesh is not None:
            # Distributed serving: params follow the role-based rules
            # (attention/FFN weights on "model" where the axis divides, the
            # quantized KAN bundles ride replicated — the runtime's
            # shard_map distributes their padded pipeline form at execution)
            # and the slot pool / KV cache shard their slot dim on "data",
            # so every decode step advances the pool data-parallel.
            from ..dist.sharding import cache_pspecs, param_pspecs, to_shardings

            params = jax.device_put(
                params, to_shardings(param_pspecs(params, mesh), mesh)
            )
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.kan_backend = kan_backend if kan_deploy else None
        # Attention backend ("ref" XLA / "flash" fused Pallas): resolved and
        # validated EAGERLY — a typo fails at engine build, and the resolved
        # name is baked into the compiled prefill/decode closures as a
        # static jit argument, so switching backends retraces instead of
        # silently reusing the other backend's step (plan-cache keying).
        self.attn_backend = runtime.resolve_attn_backend(attn_backend)
        if prefill_buckets is None:
            prefill_buckets = prefill_bucketing_supported(cfg)
        self.prefill_buckets = prefill_buckets and prefill_bucketing_supported(cfg)

        # -- paged KV pool (kv_block_size set) vs contiguous per-slot slab --
        self.paged = kv_block_size is not None
        self.kv_block_size = kv_block_size
        self.prefill_chunk = prefill_chunk
        if prefill_chunk is not None and not self.paged:
            raise ValueError("prefill_chunk requires the paged KV cache "
                             "(set kv_block_size)")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.pool = None
        if self.paged:
            if not paged_kv_supported(cfg):
                raise ValueError(
                    "kv_block_size requires a pure global-attention decoder "
                    "(rolling-window / recurrent / encoder state has no pages)"
                )
            if kv_block_size < 1 or kv_block_size % 8:
                # the flash kernel tiles KV in multiples of 8 rows; a block
                # must never straddle a KV tile
                raise ValueError(f"kv_block_size must be a positive multiple "
                                 f"of 8, got {kv_block_size}")
            if max_len % kv_block_size:
                raise ValueError(f"max_len={max_len} not a multiple of "
                                 f"kv_block_size={kv_block_size}")
            nblk = max_len // kv_block_size
            num_blocks = (kv_blocks if kv_blocks is not None
                          else slots * nblk + 1)  # +1: the scratch block
            if mesh is not None:
                # round the pool dim up so it shards evenly on "data"
                dsize = dict(zip(mesh.axis_names, mesh.devices.shape)
                             ).get("data", 1)
                num_blocks += (-num_blocks) % max(dsize, 1)
            self.pool = KVBlockPool(num_blocks, kv_block_size,
                                    prefix_cache=prefix_cache)
            # table row entry 0 = the scratch block (unallocated / retired)
            self.block_tables = np.zeros((slots, nblk), np.int32)
            self._slot_blocks: list[list[int]] = [[] for _ in range(slots)]
            self.cache = M.init_paged_cache(params, cfg, num_blocks,
                                            kv_block_size)
        else:
            self.cache = M.init_cache(params, cfg, slots, max_len)
        self._slots_sharded = False
        if mesh is not None:
            from jax.sharding import PartitionSpec

            if self.paged:
                from ..dist.sharding import paged_cache_pspecs

                cspecs = paged_cache_pspecs(self.cache, mesh,
                                            self.pool.num_blocks)
            else:
                cspecs = cache_pspecs(self.cache, mesh, slots)
            # report what the pspec rules actually decided (the CLI banner
            # echoes this) instead of re-deriving their divisibility rule
            self._slots_sharded = any(
                "data" in tuple(s) for s in jax.tree.leaves(
                    cspecs, is_leaf=lambda x: isinstance(x, PartitionSpec)
                )
            )
            self.cache = jax.device_put(
                self.cache, to_shardings(cspecs, mesh)
            )
        self.pos = np.zeros(slots, np.int32)
        self.active: list[Request | None] = [None] * slots
        # explicit free-slot list (sorted; lowest slot first, matching the
        # old linear scan's order) — O(log slots) take/release instead of an
        # O(slots) scan per admission.  A slot is NOT free while it is
        # mid-prefill (chunked prefill holds it across rounds).
        self._free_slots: list[int] = list(range(slots))
        self._prefilling: dict[int, dict] = {}  # slot -> chunked-prefill state
        self.prefill_traces = 0
        self.decode_traces = 0
        self.verify_traces = 0

        cfg_ = cfg
        eng = self

        if self.paged:
            @functools.partial(jax.jit, static_argnames=("attn_backend",))
            def _decode_paged(params, cache, token, pos, tables, attn_backend):
                eng.decode_traces += 1  # python body runs only while tracing
                with runtime.use_attn_backend(attn_backend):
                    return M.decode_step(params, cache, token, pos, cfg_,
                                         block_table=tables)

            self._decode = functools.partial(
                _decode_paged, attn_backend=self.attn_backend)

            @functools.partial(jax.jit, static_argnames=("attn_backend",))
            def _prefill_chunk_fn(params, cache, tokens, table, start,
                                  real_end, last_index, attn_backend):
                eng.prefill_traces += 1
                with runtime.use_attn_backend(attn_backend):
                    return M.prefill_chunk(params, tokens, cache, table,
                                           start, real_end, cfg_, last_index)

            self._prefill_chunk_fn = functools.partial(
                _prefill_chunk_fn, attn_backend=self.attn_backend)

            @functools.partial(jax.jit, static_argnames=("attn_backend",))
            def _verify_fn(params, cache, tokens, pos, tables, attn_backend):
                eng.verify_traces += 1  # python body runs only while tracing
                with runtime.use_attn_backend(attn_backend):
                    return M.verify_step(params, cache, tokens, pos, cfg_,
                                         tables)

            self._verify = functools.partial(
                _verify_fn, attn_backend=self.attn_backend)
        else:
            @functools.partial(jax.jit, static_argnames=("attn_backend",))
            def _decode(params, cache, token, pos, attn_backend):
                eng.decode_traces += 1  # python body runs only while tracing
                with runtime.use_attn_backend(attn_backend):
                    return M.decode_step(params, cache, token, pos, cfg_)

            self._decode = functools.partial(_decode,
                                             attn_backend=self.attn_backend)

            @functools.partial(jax.jit, static_argnames=("attn_backend",))
            def _prefill_one(params, tokens, last_index, attn_backend):
                eng.prefill_traces += 1
                with runtime.use_attn_backend(attn_backend):
                    return M.prefill(params, {"tokens": tokens}, cfg_,
                                     max_len=max_len, last_index=last_index)

            self._prefill_one = functools.partial(
                _prefill_one, attn_backend=self.attn_backend)

        # -- speculative decoding (spec_decode=k) ---------------------------
        self.spec_k = int(spec_decode or 0)
        self.draft = None
        if self.spec_k < 0:
            raise ValueError(f"spec_decode must be >= 0, got {spec_decode}")
        if self.spec_k:
            if not kan_deploy:
                raise ValueError(
                    "spec_decode requires kan_deploy=True: the drafter is "
                    "refit from the deployed target's KAN-FFN weights")
            if not self.paged:
                raise ValueError(
                    "spec_decode requires the paged KV cache (set "
                    "kv_block_size): draft rollback releases pool blocks")
            from .spec import DraftModel, DraftSpec

            dspec = (draft_spec if isinstance(draft_spec, DraftSpec)
                     else DraftSpec.parse(draft_spec))
            self.draft = DraftModel(
                float_params, cfg, dspec, slots, max_len,
                kan_backend=self.kan_backend,
                attn_backend=self.attn_backend, mesh=mesh,
            )
        elif draft_spec is not None:
            raise ValueError("draft_spec without spec_decode=k has no effect")

    # -- slot management ------------------------------------------------

    def _free_slot(self):
        """Lowest free slot id, or None — O(1) via the free-slot list."""
        return self._free_slots[0] if self._free_slots else None

    def _take_slot(self, slot: int) -> None:
        i = bisect.bisect_left(self._free_slots, slot)
        if i == len(self._free_slots) or self._free_slots[i] != slot:
            raise RuntimeError(f"slot {slot} is not free "
                               f"(free list: {self._free_slots})")
        self._free_slots.pop(i)

    def release_slot(self, slot: int) -> None:
        """Retire a slot: deactivate it, return its KV blocks to the pool
        (paged) and put it back on the free list.  The scheduler calls this
        when a request finishes; pairs with ``_begin_prefill``/``_admit``."""
        self.active[slot] = None
        self._prefilling.pop(slot, None)
        if self.draft is not None:
            self.draft.release(slot)
        if self.paged:
            for bid in self._slot_blocks[slot]:
                self.pool.release(bid)
            self._slot_blocks[slot] = []
            # point the row at the scratch block: a retired slot still rides
            # the pooled decode step, and its writes must land nowhere real
            self.block_tables[slot] = 0
        bisect.insort(self._free_slots, slot)

    def _padded_prompt(self, prompt: list) -> list:
        """Right-pad to the power-of-two length bucket (token 0 as filler)."""
        if not self.prefill_buckets:
            return list(prompt)
        lb = runtime.bucket_batch(len(prompt))
        if lb > self.max_len - 1:
            return list(prompt)
        return list(prompt) + [0] * (lb - len(prompt))

    def _admit(self, req: Request):
        """Prefill ``req`` into a free slot and greedily pick its first token.

        The scheduler calls :meth:`_prefill_slot` directly (it owns token
        selection — sampling — and metrics); this wrapper keeps the classic
        greedy admission for direct engine use.
        """
        slot = self._free_slot()
        if slot is None:
            raise RuntimeError(
                f"ServeEngine._admit: no free slot for request {req.rid} "
                f"(all {self.slots} busy); check _free_slot() before admitting"
            )
        logits = self._prefill_slot(slot, req)
        req.output.append(int(np.argmax(logits)))

    def _prefill_slot(self, slot: int, req: Request) -> np.ndarray:
        """B=1 prefill of ``req`` into pool ``slot``; returns the (V,)
        first-token logits.  Fills the prompt's cache and activates the
        slot — everything about admission EXCEPT choosing the first token,
        which the caller does (greedy in ``_admit``, sampling and timing in
        the scheduler).  Runs the WHOLE prefill synchronously; the chunked
        path (``_begin_prefill`` + ``_prefill_step`` per scheduling round)
        is how the scheduler keeps a long prompt from stalling the pool."""
        self._begin_prefill(slot, req)
        logits = self._prefill_step(slot)
        while logits is None:
            logits = self._prefill_step(slot)
        return logits

    def _begin_prefill(self, slot: int, req: Request) -> None:
        """Claim ``slot`` for ``req`` and stage its prefill.

        Paged engines match the prompt against the prefix cache here: the
        longest cached FULL-block chain (capped at ``plen - 1`` tokens so
        at least one real token is always prefilled — the first-token
        logits must be computed from something) is spliced into the block
        table copy-free, and prefill starts after it."""
        self._take_slot(slot)
        state = {"req": req, "next": 0}
        if self.paged:
            reused = self.pool.match_prefix(req.prompt,
                                            max_tokens=len(req.prompt) - 1)
            self._slot_blocks[slot] = list(reused)
            for j, bid in enumerate(reused):
                self.block_tables[slot, j] = bid
            state["next"] = len(reused) * self.kv_block_size
        self._prefilling[slot] = state

    def prefilling_slots(self) -> list:
        """Slots currently mid-prefill (claimed, not yet decoding)."""
        return sorted(self._prefilling)

    def _prefill_step(self, slot: int):
        """Advance ``slot``'s staged prefill by one chunk.

        Returns the (V,) first-token logits when the prompt completes (the
        slot becomes active), else None.  Contiguous engines complete in
        one step (the classic whole-prompt prefill + cache splice); paged
        engines advance ``prefill_chunk`` tokens (everything remaining when
        unset) into pool blocks allocated on demand."""
        st = self._prefilling[slot]
        req = st["req"]
        if not self.paged:
            logits = self._prefill_contiguous(slot, req)
        else:
            logits = self._prefill_paged_chunk(slot, st)
            if logits is None:
                return None
        self.pos[slot] = len(req.prompt)
        self.active[slot] = req
        del self._prefilling[slot]
        if self.draft is not None:
            # the drafter needs the prompt in its OWN cache before it can
            # propose for this slot; one cheap B=1 drafter prefill here
            self.draft.prefill_slot(slot, req)
        return logits

    def _prefill_contiguous(self, slot: int, req: Request) -> np.ndarray:
        plen = len(req.prompt)
        # prefill the request alone (B=1), splice its cache into the pool
        tokens = jnp.asarray([self._padded_prompt(req.prompt)], jnp.int32)
        with runtime.use_backend(self.kan_backend), \
                runtime.use_mesh(self.mesh), \
                profile_scope("serve.prefill"):
            logits, cache1 = self._prefill_one(
                self.params, tokens, jnp.asarray([plen - 1], jnp.int32)
            )
        # mask the padding in the cache splice: KV written past the real
        # prompt (pad tokens) is zeroed so no stale state enters the pool.
        tmask = jnp.arange(self.max_len) < plen

        def splice(pool, one):
            one = one[:, 0]                      # (repeats, T, H, D)
            if (self.prefill_buckets and one.ndim >= 2
                    and one.shape[1] == self.max_len):
                one = jnp.where(
                    tmask.reshape((1, -1) + (1,) * (one.ndim - 2)), one, 0
                )
            return pool.at[:, slot].set(one)

        self.cache = jax.tree.map(splice, self.cache, cache1)
        return np.asarray(logits[0])

    def _prefill_paged_chunk(self, slot: int, st: dict):
        """One chunk of paged prefill; returns final logits or None."""
        req = st["req"]
        plen = len(req.prompt)
        start = st["next"]
        cap = self.prefill_chunk if self.prefill_chunk is not None else plen
        take = min(plen - start, cap)
        # pad the chunk to a power-of-two bucket (same O(log L) compile
        # policy as contiguous prefill) unless that would run past max_len
        c = take
        if self.prefill_buckets:
            lb = runtime.bucket_batch(take)
            if start + lb <= self.max_len:
                c = lb
        bs = self.kv_block_size
        blocks = self._slot_blocks[slot]
        need = -(-(start + take) // bs)          # ceil: blocks covering chunk
        try:
            while len(blocks) < need:
                bid = self.pool.alloc()
                self.block_tables[slot, len(blocks)] = bid
                blocks.append(bid)
        except Exception:
            self.release_slot(slot)
            raise
        chunk = req.prompt[start:start + take] + [0] * (c - take)
        tokens = jnp.asarray([chunk], jnp.int32)
        table = jnp.asarray(self.block_tables[slot])
        with runtime.use_backend(self.kan_backend), \
                runtime.use_mesh(self.mesh), \
                profile_scope("serve.prefill_chunk"):
            logits, self.cache = self._prefill_chunk_fn(
                self.params, self.cache, tokens, table,
                jnp.asarray(start, jnp.int32),
                jnp.asarray(start + take, jnp.int32),
                jnp.asarray(plen - 1, jnp.int32),
            )
        st["next"] = start + take
        if st["next"] < plen:
            return None
        # publish the prompt's FULL blocks for future prefix hits (cached
        # prefix blocks re-publish as no-ops); partial tail blocks — which
        # decode will keep writing — are never shared
        self.pool.publish_prefix(req.prompt, blocks[:plen // bs])
        return np.asarray(logits[0])

    def _ensure_decode_blocks(self, horizon: int = 1) -> None:
        """Allocate the pool blocks covering each active slot's next
        ``horizon`` writes (positions ``pos .. pos+horizon-1``, clamped at
        ``max_len`` — writes past it are dropped on device); runs on host
        each round.  ``horizon=1`` is the classic one-token decode step
        (at most one block per slot per call); the speculative verify pass
        needs ``spec_k + 1``."""
        bs = self.kv_block_size
        for i, r in enumerate(self.active):
            if r is None:
                continue
            blocks = self._slot_blocks[i]
            need = -(-min(int(self.pos[i]) + horizon, self.max_len) // bs)
            while len(blocks) < need:
                bid = self.pool.alloc()
                self.block_tables[i, len(blocks)] = bid
                blocks.append(bid)

    def decode_active(self, tokens) -> jax.Array:
        """One pooled decode step over all slots; returns device logits
        (slots, V) and updates the cache in place.  ``pos`` bookkeeping is
        the caller's (the scheduler advances it after selecting tokens)."""
        args = ()
        if self.paged:
            self._ensure_decode_blocks()
            tables = self.block_tables
            if self._prefilling:
                # mid-prefill slots ride the pooled step with a stale pos;
                # point their rows at the scratch block so the step's KV
                # write can't corrupt the blocks their prefill is filling
                tables = tables.copy()
                for s in self._prefilling:
                    tables[s] = 0
            args = (jnp.asarray(tables),)
        with runtime.use_backend(self.kan_backend), \
                runtime.use_mesh(self.mesh), \
                profile_scope("serve.decode_step"):
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens, jnp.int32),
                jnp.asarray(self.pos), *args,
            )
        return logits

    def verify_active(self, tokens) -> jax.Array:
        """One batched speculative VERIFY pass over all slots.

        ``tokens``: (slots, S) int32, S = spec_k + 1 — row i is the slot's
        last emitted token followed by its draft tokens, occupying
        positions ``pos[i] .. pos[i]+S-1``.  Returns device logits
        (slots, S, V); row j is bit-identical to what ``decode_active``
        would produce after consuming rows 0..j-1 one at a time (see
        ``models.model.verify_step``).  KV for all S positions is written;
        the caller rolls back rejected positions with
        :meth:`truncate_slot`.  ``pos`` bookkeeping is the caller's, same
        as ``decode_active``."""
        s = int(tokens.shape[1])
        self._ensure_decode_blocks(horizon=s)
        tables = self.block_tables
        if self._prefilling:
            # mid-prefill slots ride along with a stale pos; scratch-redirect
            # their rows exactly as decode_active does
            tables = tables.copy()
            for sl in self._prefilling:
                tables[sl] = 0
        with runtime.use_backend(self.kan_backend), \
                runtime.use_mesh(self.mesh), \
                profile_scope("serve.verify"):
            logits, self.cache = self._verify(
                self.params, self.cache, jnp.asarray(tokens, jnp.int32),
                jnp.asarray(self.pos), jnp.asarray(tables),
            )
        return logits

    def truncate_slot(self, slot: int, new_len: int) -> None:
        """Roll back a slot's KV to ``new_len`` positions after a verify
        round rejected draft tokens: whole tail blocks return to the pool
        (``kvpool.truncate`` guards cached prefix blocks) and their table
        rows point back at the scratch block.  Rejected rows inside the
        kept partial tail block stay — the next verify re-writes them
        before any query can attend them."""
        blocks = self._slot_blocks[slot]
        self.pool.truncate(blocks, new_len)
        self.block_tables[slot, len(blocks):] = 0

    def kv_stats(self) -> dict | None:
        """Paged-pool observability (None on contiguous engines)."""
        if not self.paged:
            return None
        s = self.pool.stats()
        s["prefill_chunk"] = self.prefill_chunk
        s["slot_blocks"] = [len(b) for b in self._slot_blocks]
        return s

    # -- main loop --------------------------------------------------------

    def run(self, requests: list, log: Callable | None = None):
        """Serve a batch synchronously; returns requests in completion order.

        Thin driver over :class:`repro.serve.scheduler.Scheduler`: submit
        everything up front (default ``arrival_s=0`` — all available
        immediately), run the event loop to idle.  FIFO admission into free
        slots + one pooled decode step per round is exactly the
        pre-scheduler loop, so greedy token streams are bit-identical to
        it; per-request deadlines/sampling fields are honored if callers
        set them.  Use the scheduler directly for streaming callbacks,
        backpressure and metrics.
        """
        from .scheduler import Scheduler

        sched = Scheduler(self, log=log)
        for req in requests:
            sched.submit(req)
        return sched.run_until_idle()

    def compile_stats(self) -> dict:
        """Engine-level trace counts + the runtime plan-cache counters."""
        return {
            "prefill_traces": self.prefill_traces,
            "decode_traces": self.decode_traces,
            "verify_traces": self.verify_traces,
            "plan_cache": runtime.cache_stats(),
            "mesh": self.mesh_layout(),
            "attn_backend": self.attn_backend,
            "kv": self.kv_stats(),
            "spec": (None if self.draft is None
                     else {"k": self.spec_k, "draft": self.draft.describe()}),
        }

    def mesh_layout(self) -> dict | None:
        """The serving mesh layout (axes x sizes + device count + whether
        the slot pool actually sharded on "data"), or None."""
        if self.mesh is None:
            return None
        return {
            "axes": list(self.mesh.axis_names),
            "shape": [int(s) for s in self.mesh.devices.shape],
            "devices": int(self.mesh.devices.size),
            "slots_sharded": self._slots_sharded,
        }

    def kan_plan_source(self) -> str | None:
        """Where the KAN-FFN pipeline geometry comes from.

        "tuned" when a ``repro.tune`` tile plan is registered for this
        engine's FFN geometry (e.g. loaded from a ``--tuned-config``
        artifact), "heuristic" for the built-in block-size heuristic, None
        when the engine is not serving a KAN-FFN deployment.
        """
        if self.cfg.ffn_kind != "kan":
            return None
        from ..models.layers import kan_ffn_hidden, kan_ffn_specs

        specs = kan_ffn_specs(self.cfg)
        d = self.cfg.d_model
        ov = runtime.PLAN_CACHE.get_tile_overrides(
            (d, kan_ffn_hidden(self.cfg), d), specs, True
        )
        return "tuned" if ov is not None else "heuristic"
