"""Async streaming serve scheduler: event-driven continuous batching.

:class:`ServeEngine` owns the slot pool, the compiled prefill/decode steps
and the KV cache; this module owns *when* those steps run.  The scheduler
turns the engine's synchronous ``run(requests)`` batch loop into an
event-driven loop over a request queue:

  * **admission / backpressure** — a bounded queue (``max_queue``; a full
    queue rejects with :class:`QueueFull`) of requests carrying arrival
    times (``Request.arrival_s``, an offset from scheduler start — future
    arrivals model a live traffic trace) and optional per-request deadlines
    (``Request.deadline_s``: the max time a request may wait in the queue
    before it is expired unserved);
  * **decoupled prefill/decode** — each scheduling round first prefills
    waiting prompts into whatever slots are free (B=1 prefill + cache
    splice, exactly the engine's admission path) and then advances ALL
    active slots with one compiled decode step, so new prompts slip into
    the pool between decode steps instead of gating on the whole batch;
  * **streaming** — per-request ``on_token(request, token)`` /
    ``on_done(request)`` callbacks fire as tokens are produced, so callers
    consume output incrementally instead of waiting for ``run()`` to
    return;
  * **sampling** — per-request :class:`SamplingParams` (temperature /
    top-k / top-p, explicitly seeded, reproducible run to run) next to the
    default greedy argmax.  Greedy requests decode **bit-identical** token
    streams to ``ServeEngine.run()`` — ``run()`` is in fact a thin
    synchronous driver over this scheduler;
  * **metrics** — per-request TTFT and inter-token latencies plus
    aggregate tokens/s, queue-depth-over-time samples and admission
    counters, snapshotted by :meth:`Scheduler.stats` (see
    ``docs/serving.md`` for the metrics glossary).  With the process-wide
    obs registry enabled (``repro.obs.enable()`` / ``REPRO_OBS=1``) the
    same events also feed the documented dotted series (``serve.ttft_s``,
    ``serve.completed``, ``kv.blocks_in_use``, ...; see
    ``docs/observability.md``) — recording only, token streams are
    bit-identical with observability on or off;
  * **tracing** — ``Scheduler(trace=True)`` records one span tree per
    request (``request`` > ``queued`` / ``prefill`` / ``decode`` +
    ``first_token`` events) on the scheduler's own clock via
    :class:`repro.obs.Tracer` (``sched.tracer``), so a ``ManualClock``
    workload exports a byte-identical JSONL timeline run to run;
  * **logging** — ``log=`` accepts the legacy bare callable (every line
    forwarded, as always) or ``None`` for the structured ``repro.obs``
    logger, where per-request chatter sits at debug level under
    ``REPRO_LOG_LEVEL``; ``stats_interval_s=`` emits a periodic one-line
    stats summary through it.

Time comes from an injectable clock (wall ``time.perf_counter`` by
default); :class:`ManualClock` makes arrival/deadline behavior
deterministic for tests and simulation.

    sched = Scheduler(engine, max_queue=64)
    sched.submit(Request(rid=0, prompt=[...]), on_token=lambda r, t: ...)
    sched.run_until_idle()
    print(sched.stats())
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs

__all__ = [
    "ManualClock",
    "QueueFull",
    "SamplingParams",
    "Scheduler",
    "sample_token",
]


class QueueFull(RuntimeError):
    """Raised by :meth:`Scheduler.submit` when the bounded queue is full."""


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode sampling policy (attach as ``Request.sampling``).

    ``temperature <= 0`` means greedy argmax — bit-identical to the
    engine's own selection, so a mixed greedy/sampled pool is safe.  With
    ``temperature > 0`` the logits are divided by the temperature, then
    restricted to the ``top_k`` highest (0 = no limit) and to the smallest
    nucleus whose probability mass reaches ``top_p``, and the token is
    drawn from the renormalized remainder.  Every draw is keyed by
    ``(seed, rid, position)`` — fixed seed, fixed stream: runs reproduce
    exactly, and concurrent requests never share a PRNG stream.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0 (0 = greedy)")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 = unrestricted)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


class ManualClock:
    """Deterministic clock for tests/simulation: time moves only on demand."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("time only moves forward")
        self._t += float(dt)


def sample_token(logits: np.ndarray, params: SamplingParams, rid: int,
                 position: int) -> int:
    """Draw one token id from a logits row under ``params``.

    Pure function of (logits, params, rid, position): the PRNG key is
    ``fold_in(fold_in(PRNGKey(seed), rid), position)``, so each request has
    its own reproducible stream regardless of scheduling order.
    """
    if params.greedy:
        return int(np.argmax(logits))
    row = np.asarray(logits, np.float64) / max(params.temperature, 1e-6)
    if 0 < params.top_k < row.size:
        kth = np.partition(row, -params.top_k)[-params.top_k]
        row = np.where(row < kth, -np.inf, row)
    if params.top_p < 1.0:
        order = np.argsort(-row, kind="stable")
        probs = np.exp(row[order] - row[order[0]])
        probs /= probs.sum()
        cum = np.cumsum(probs)
        # smallest prefix with mass >= top_p; the head token always stays
        cut = int(np.searchsorted(cum, params.top_p)) + 1
        drop = order[cut:]
        row[drop] = -np.inf
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(params.seed), rid), position
    )
    return int(jax.random.categorical(key, jnp.asarray(row, jnp.float32)))


def _pct(xs: list, q: float) -> float | None:
    """Nearest-rank percentile of a small sample (None when empty)."""
    if not xs:
        return None
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, round(q * (len(s) - 1))))]


def _summary(xs: list) -> dict:
    return {
        "n": len(xs),
        "mean": (sum(xs) / len(xs)) if xs else None,
        "p50": _pct(xs, 0.50),
        "p95": _pct(xs, 0.95),
    }


# KV-pool counters mirrored into gauges each scheduling round (paged
# engines only; names documented in docs/observability.md).
_KV_GAUGES = (
    "blocks_in_use", "blocks_in_use_peak", "blocks_cached", "blocks_free",
    "prefix_hits", "prefix_misses", "allocs", "evictions", "truncations",
)


class Scheduler:
    """Event-driven continuous batching over one :class:`ServeEngine`.

    The scheduler mutates the engine's slot pool / cache through the same
    internals ``run()`` used (``_free_slot`` / ``_prefill_slot`` /
    ``_decode``); one scheduler per engine at a time.
    """

    def __init__(self, engine, max_queue: int | None = None, clock=None,
                 log: Callable | None = None, trace: bool = False,
                 tracer=None, stats_interval_s: float | None = None):
        self.engine = engine
        self.max_queue = max_queue
        self._clock = clock
        self._now = clock.now if clock is not None else time.perf_counter
        self._t0 = self._now()
        # bare callables keep their legacy everything-forwarded behavior;
        # None routes through the structured process logger (info threshold,
        # REPRO_LOG_LEVEL) where per-request chatter sits at debug level
        self.log = obs.as_logger(log, "sched")
        self.stats_interval_s = stats_interval_s
        self._last_stats_line = 0.0
        # span recorder on the scheduler's own clock: ManualClock workloads
        # trace deterministically (byte-identical JSONL run to run)
        self.tracer = tracer
        if trace and self.tracer is None:
            self.tracer = obs.Tracer(clock=self.elapsed)
        self._spans: dict[int, dict] = {}      # ACTIVE rid -> span handles
        self._mx = self._bind_metrics() if obs.enabled() else None
        self.queue: list = []                  # submitted, not yet admitted
        self.finished: list = []               # completion order (+ expired)
        self._on_token: dict[int, Callable] = {}
        self._on_done: dict[int, Callable] = {}
        self._rec: dict[int, dict] = {}        # ACTIVE rid -> timing record
        self.submitted = 0
        self.completed = 0
        self.expired = 0
        self.rejected = 0
        self.decode_steps = 0
        # bounded metric state: per-request records live only while the
        # request is active (<= slots of them); finished requests leave
        # behind scalars/capped samples, so a long-lived scheduler's
        # footprint does not grow with total requests served.  finished
        # itself is the caller's to drain (drain_finished()).
        self._ttfts: collections.deque = collections.deque(maxlen=4096)
        self._itls: collections.deque = collections.deque(maxlen=4096)
        self._tokens_done = 0                  # tokens of finished requests
        # decode-round shape: tokens emitted per (active slot, round) pair —
        # exactly 1.0 without speculative decoding, 1 + accepted/round with
        self._round_tokens = 0
        self._round_slots = 0
        # speculative-decode aggregates (engine.spec_k > 0 rounds only)
        self._spec_rounds = 0
        self._spec_drafted = 0
        self._spec_accepted = 0
        self._draft_s: collections.deque = collections.deque(maxlen=4096)
        self._verify_s: collections.deque = collections.deque(maxlen=4096)
        self._span_start: float | None = None  # first admission
        self._span_end: float | None = None    # last emitted token
        self._depth_samples: collections.deque = collections.deque(
            maxlen=4096)                       # (elapsed_s, depth) trace tail
        self._depth_rounds = 0
        self._depth_sum = 0
        self._depth_max = 0

    def _bind_metrics(self) -> dict:
        """Resolve the serve.* / kv.* instruments once at construction so
        the per-round record path is attribute access, not registry
        lookups.  Only called when obs is enabled; ``self._mx is None``
        otherwise and every obs block below is skipped outright."""
        R = obs.REGISTRY
        mx = {
            "submitted": R.counter("serve.submitted"),
            "completed": R.counter("serve.completed"),
            "expired": R.counter("serve.expired"),
            "rejected": R.counter("serve.rejected"),
            "tokens": R.counter("serve.tokens"),
            "decode_steps": R.counter("serve.decode_steps"),
            "queue_depth": R.gauge("serve.queue_depth"),
            "active": R.gauge("serve.active_slots"),
            "prefilling": R.gauge("serve.prefilling_slots"),
            "ttft": R.histogram("serve.ttft_s"),
            "itl": R.histogram("serve.itl_s"),
            "spec_drafted": R.counter("serve.spec.drafted"),
            "spec_accepted": R.counter("serve.spec.accepted"),
            "spec_draft_s": R.histogram("serve.spec.draft_s"),
            "spec_verify_s": R.histogram("serve.spec.verify_s"),
        }
        for k in _KV_GAUGES:
            mx[f"kv.{k}"] = R.gauge(f"kv.{k}")
        return mx

    # -- time -------------------------------------------------------------

    def elapsed(self) -> float:
        """Seconds since scheduler construction (the arrival_s timebase)."""
        return self._now() - self._t0

    def _wait(self, dt: float) -> None:
        if dt <= 0:
            return
        if self._clock is not None and hasattr(self._clock, "advance"):
            self._clock.advance(dt)
        else:
            time.sleep(dt)

    # -- submission -------------------------------------------------------

    def submit(self, req, on_token: Callable | None = None,
               on_done: Callable | None = None):
        """Enqueue a request; raises :class:`QueueFull` on backpressure.

        ``req.arrival_s`` earlier than now is bumped to the submission
        instant (you cannot arrive in the past); a future value keeps the
        request invisible to admission until that offset — the hook the
        sustained-load benchmark drives its deterministic arrival schedule
        through.
        """
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.rejected += 1
            if self._mx is not None:
                self._mx["rejected"].inc()
            raise QueueFull(
                f"queue full ({len(self.queue)}/{self.max_queue}); "
                f"request {req.rid} rejected"
            )
        req.arrival_s = max(float(req.arrival_s), self.elapsed())
        req.status = "queued"
        self.queue.append(req)
        self.submitted += 1
        if self._mx is not None:
            self._mx["submitted"].inc()
        if self.tracer is not None:
            root = self.tracer.begin(
                "request", rid=req.rid, prompt_tokens=len(req.prompt),
                max_new_tokens=req.max_new_tokens,
            )
            self._spans[req.rid] = {
                "request": root,
                "queued": self.tracer.begin("queued", parent=root),
            }
        self._on_token[req.rid] = on_token
        self._on_done[req.rid] = on_done
        return req

    # -- one scheduling round --------------------------------------------

    def step(self) -> bool:
        """Expire, admit, advance staged prefills, then one decode step.

        With chunked prefill (``engine.prefill_chunk``) each round advances
        every mid-prefill slot by ONE chunk before the pooled decode, so a
        long prompt prefills interleaved with decode instead of stalling
        the whole pool; without it admission prefills whole prompts
        synchronously (the classic path — greedy streams bit-identical to
        the pre-scheduler loop).

        Returns True if any progress was made (a prefill or a decode ran);
        False means the scheduler is idle right now — either fully drained,
        or every queued request has a future arrival time.
        """
        now = self.elapsed()
        self._expire(now)
        progressed = self._admit_arrived(now)
        progressed = self._advance_prefills() or progressed
        depth = len(self.queue)
        self._depth_samples.append((now, depth))
        self._depth_rounds += 1
        self._depth_sum += depth
        self._depth_max = max(self._depth_max, depth)
        if self._mx is not None:
            self._sample_gauges(depth)
        if (self.stats_interval_s is not None
                and now - self._last_stats_line >= self.stats_interval_s):
            self._last_stats_line = now
            self._stats_line(now)
        if any(r is not None for r in self.engine.active):
            if self.engine.spec_k:
                self._spec_round()
            else:
                self._decode_round()
            progressed = True
        return progressed

    def run_until_idle(self) -> list:
        """Drive :meth:`step` until queue and pool drain; returns finished.

        When the only remaining work is a future arrival, the scheduler
        waits for it (``time.sleep`` on the wall clock, ``advance`` on a
        :class:`ManualClock`).
        """
        eng = self.engine
        while (self.queue or any(r is not None for r in eng.active)
               or eng.prefilling_slots()):
            if not self.step() and self.queue:
                nxt = min(r.arrival_s for r in self.queue)
                self._wait(nxt - self.elapsed())
        return self.finished

    # -- internals --------------------------------------------------------

    def _expire(self, now: float) -> None:
        keep = []
        for r in self.queue:
            if (r.deadline_s is not None
                    and now - r.arrival_s > r.deadline_s):
                r.done = True
                r.status = "expired"
                self.expired += 1
                if self._mx is not None:
                    self._mx["expired"].inc()
                self._trace_finish(r, "expired")
                self.finished.append(r)
                self._finish_cb(r)
                self._retire(r.rid)
                self.log.debug("request expired", rid=r.rid,
                               queued_s=round(now - r.arrival_s, 3))
            else:
                keep.append(r)
        self.queue = keep

    def _admit_arrived(self, now: float) -> bool:
        eng = self.engine
        admitted = False
        while True:
            slot = eng._free_slot()
            if slot is None:
                break
            idx = next(
                (i for i, r in enumerate(self.queue) if r.arrival_s <= now),
                None,
            )
            if idx is None:
                break
            req = self.queue.pop(idx)
            if eng.prefill_chunk is not None:
                # chunked prefill: claim the slot now, advance one chunk per
                # round (_advance_prefills) — the first token is emitted
                # when the prompt completes
                self._trace_admit(req, chunked=True)
                eng._begin_prefill(slot, req)
                req.status = "running"
                admitted = True
                self.log.debug("admitted request", rid=req.rid,
                               prefill="chunked", queued=len(self.queue))
                continue
            self._trace_admit(req, chunked=False)
            logits = eng._prefill_slot(slot, req)
            self._first_token(req, logits)
            admitted = True
            self.log.debug("admitted request", rid=req.rid,
                           queued=len(self.queue))
        return admitted

    def _advance_prefills(self) -> bool:
        """One chunk of progress for every mid-prefill slot (chunked mode);
        emits the first token of any prompt that completes this round."""
        eng = self.engine
        progressed = False
        for slot in eng.prefilling_slots():
            req = eng._prefilling[slot]["req"]
            logits = eng._prefill_step(slot)
            progressed = True
            if self.tracer is not None:
                sp = self._spans.get(req.rid)
                if sp is not None and "chunks" in sp:
                    sp["chunks"] += 1
            if logits is not None:
                self._first_token(req, logits)
                self.log.debug("prefill complete", rid=req.rid)
        return progressed

    def _first_token(self, req, logits) -> None:
        """Select and record a freshly-prefilled request's first token."""
        t = self.elapsed()
        tok = self._select(req, logits)
        req.output.append(tok)
        req.status = "running"
        req.ttft_s = t - req.arrival_s
        self._ttfts.append(req.ttft_s)
        if self._mx is not None:
            self._mx["ttft"].observe(req.ttft_s)
        if self.tracer is not None:
            sp = self._spans.get(req.rid)
            if sp is not None:
                pre = sp.pop("prefill", None)
                if pre is not None:
                    self.tracer.end(pre, chunks=sp.pop("chunks", 0))
                self.tracer.event("first_token", parent=sp["request"],
                                  ttft_s=round(req.ttft_s, 9))
                sp["decode"] = self.tracer.begin("decode",
                                                 parent=sp["request"])
        self._rec[req.rid] = {
            "arrival": req.arrival_s, "admit": t, "token_times": [t],
        }
        if self._span_start is None or t < self._span_start:
            self._span_start = t
        self._span_end = t
        self._emit(req, tok)

    def _decode_round(self) -> None:
        eng = self.engine
        tokens = np.zeros(eng.slots, np.int32)
        for i, r in enumerate(eng.active):
            if r is not None:
                tokens[i] = r.output[-1]
        logits = eng.decode_active(tokens)
        self.decode_steps += 1
        if self._mx is not None:
            self._mx["decode_steps"].inc()
        # pure-greedy pools (the common case, and all of run()) take the
        # device-side argmax — transferring B ints per step, not the whole
        # (slots, vocab) logits matrix; the full rows come to host only
        # when some active request actually samples
        if any(getattr(r, "sampling", None) is not None
               for r in eng.active if r is not None):
            rows, nxt = np.asarray(logits), None
        else:
            rows, nxt = None, np.asarray(jnp.argmax(logits, axis=-1))
        t = self.elapsed()
        self._span_end = t
        for i, r in enumerate(eng.active):
            if r is None:
                continue
            eng.pos[i] += 1
            tok = int(nxt[i]) if rows is None else self._select(r, rows[i])
            # a slot admitted behind the scheduler's back (direct
            # ServeEngine._admit) is adopted on its first decode: timing
            # starts now, its prefill token predates the record
            rec = self._rec.setdefault(
                r.rid, {"arrival": r.arrival_s, "admit": t, "token_times": []}
            )
            self._round_tokens += 1
            self._round_slots += 1
            self._emit_tokens(r, rec, [tok], t)
            if (tok == r.eos_id or len(r.output) >= r.max_new_tokens
                    or eng.pos[i] >= eng.max_len - 1):
                self._finish_request(r, i, t, rec)

    def _spec_round(self) -> None:
        """One speculative decode round: propose -> verify -> accept/emit.

        The drafter proposes ``spec_k`` greedy tokens per active slot; the
        target scores all k+1 positions in ONE batched forward
        (``verify_active``); the longest draft prefix matching the
        target's own greedy argmax is accepted, and the matching argmax
        tokens plus the first-mismatch correction are emitted through the
        SAME per-token finish checks as :meth:`_decode_round`.  Every
        emitted token is the argmax of the exact logits row the
        sequential baseline would have produced (``verify_step`` is
        row-for-row bit-identical to ``decode_step``), so greedy streams
        are bit-identical to ``spec_decode=0`` — speculation only decides
        how many rows are consumed per round.  Sampled requests emit ONE
        token from row 0 under the classic ``(seed, rid, position)`` key
        schedule, keeping their streams bit-identical too (their drafts
        are simply discarded).  Rejected draft KV rolls back via
        ``truncate_slot`` / ``draft.truncate``.
        """
        eng = self.engine
        k = eng.spec_k
        draft = eng.draft
        active = [(i, r) for i, r in enumerate(eng.active) if r is not None]
        # catch-up token lists: the true tokens at drafter positions
        # dpos..pos inclusive (one entry at steady state; two after a
        # fully-accepted round — see DraftModel.propose)
        pend = {}
        for i, r in active:
            plen = len(r.prompt)
            lo, hi = int(draft.pos[i]), int(eng.pos[i])
            pend[i] = [r.prompt[p] if p < plen else r.output[p - plen]
                       for p in range(lo, hi + 1)]
        t0 = time.perf_counter()
        drafts = draft.propose(pend, k)
        t1 = time.perf_counter()
        tokens = np.zeros((eng.slots, k + 1), np.int32)
        for i, r in active:
            tokens[i, 0] = r.output[-1]
            tokens[i, 1:] = drafts[i]
        logits = eng.verify_active(tokens)
        self.decode_steps += 1
        self._spec_rounds += 1
        if self._mx is not None:
            self._mx["decode_steps"].inc()
        # pure-greedy pools take the device-side argmax — (slots, k+1) ints
        # per round, not the logits cube; full rows come to host only when
        # some active request actually samples
        if any(getattr(r, "sampling", None) is not None for _, r in active):
            rows = np.asarray(logits)                       # (slots, k+1, V)
            g = np.argmax(rows, axis=-1)
        else:
            rows = None
            g = np.asarray(jnp.argmax(logits, axis=-1))     # (slots, k+1)
        t2 = time.perf_counter()
        self._draft_s.append(t1 - t0)
        self._verify_s.append(t2 - t1)
        if self._mx is not None:
            self._mx["spec_draft_s"].observe(t1 - t0)
            self._mx["spec_verify_s"].observe(t2 - t1)
        t = self.elapsed()
        self._span_end = t
        for i, r in active:
            rec = self._rec.setdefault(
                r.rid, {"arrival": r.arrival_s, "admit": t, "token_times": []}
            )
            sampled = getattr(r, "sampling", None) is not None
            if sampled:
                toks = [self._select(r, rows[i, 0])]
            else:
                m = 0
                while m < k and int(tokens[i, m + 1]) == int(g[i, m]):
                    m += 1
                toks = [int(g[i, j]) for j in range(m + 1)]
                self._spec_drafted += k
                self._spec_accepted += m
                if self._mx is not None:
                    self._mx["spec_drafted"].inc(k)
                    self._mx["spec_accepted"].inc(m)
            # accepted tokens still pass the baseline's PER-TOKEN finish
            # checks: acceptance can never run past EOS / max_new_tokens /
            # the max_len position cap (tokens after the finish point are
            # discarded, exactly as the baseline would never produce them)
            emit = []
            out_len = len(r.output)
            posi = int(eng.pos[i])
            finished = False
            for tok in toks:
                posi += 1
                out_len += 1
                emit.append(tok)
                if (tok == r.eos_id or out_len >= r.max_new_tokens
                        or posi >= eng.max_len - 1):
                    finished = True
                    break
            eng.pos[i] = posi
            self._round_tokens += len(emit)
            self._round_slots += 1
            self._emit_tokens(r, rec, emit, t)
            if finished:
                self._finish_request(r, i, t, rec)
            else:
                # roll back the rejected speculative KV tail on both models
                eng.truncate_slot(i, posi)
                draft.truncate(i, posi)

    def _finish_request(self, r, slot: int, t: float, rec: dict) -> None:
        r.done = True
        r.status = "done"
        r.latency_s = t - rec["admit"]
        self.completed += 1
        if self._mx is not None:
            self._mx["completed"].inc()
        self._trace_finish(r, "done")
        self.finished.append(r)
        self.engine.release_slot(slot)
        self._finish_cb(r)
        self._retire(r.rid)
        self.log.debug("request done", rid=r.rid, tokens=len(r.output),
                       latency_s=round(r.latency_s, 3))

    def _emit_tokens(self, r, rec: dict, toks: list, t: float) -> None:
        """Record + stream tokens emitted together at wall instant ``t``.

        Multi-token acceptance (speculative decode) lands n > 1 tokens of
        one request in one round; inter-token latency stays
        per-EMITTED-token by spreading the round's wall time uniformly
        across them — each gap records as (t - last) / n, which at n = 1
        is exactly the classic per-round ITL."""
        times = rec["token_times"]
        n = len(toks)
        last = times[-1] if times else t
        for j, tok in enumerate(toks, start=1):
            tj = t if j == n else last + (t - last) * (j / n)
            r.output.append(tok)
            if self._mx is not None and times:
                self._mx["itl"].observe(tj - times[-1])
            times.append(tj)
            self._emit(r, tok)

    def _retire(self, rid: int) -> None:
        """Fold a finished request's record into the capped aggregates and
        drop all per-request state (records live only while active)."""
        rec = self._rec.pop(rid, None)
        if rec is not None:
            times = rec["token_times"]
            self._tokens_done += len(times)
            self._itls.extend(b - a for a, b in zip(times, times[1:]))
        self._on_token.pop(rid, None)
        self._on_done.pop(rid, None)

    def _select(self, req, logits_row: np.ndarray) -> int:
        sp = getattr(req, "sampling", None)
        if sp is None:
            return int(np.argmax(logits_row))
        return sample_token(logits_row, sp, req.rid, len(req.output))

    def _emit(self, req, tok: int) -> None:
        if self._mx is not None:
            self._mx["tokens"].inc()
        cb = self._on_token.get(req.rid)
        if cb is not None:
            cb(req, tok)

    def _finish_cb(self, req) -> None:
        cb = self._on_done.get(req.rid)
        if cb is not None:
            cb(req)

    # -- obs hooks (no-ops unless tracing / metrics are enabled) -----------

    def _trace_admit(self, req, chunked: bool) -> None:
        """queued span ends, prefill span opens (admission instant)."""
        if self.tracer is None:
            return
        sp = self._spans.get(req.rid)
        if sp is None:
            return  # submitted before tracing was attached
        q = sp.pop("queued", None)
        if q is not None:
            self.tracer.end(q)
        sp["prefill"] = self.tracer.begin("prefill", parent=sp["request"],
                                          chunked=chunked)
        sp["chunks"] = 0

    def _trace_finish(self, req, status: str) -> None:
        """Close the request's whole span tree (done or expired)."""
        if self.tracer is None:
            return
        sp = self._spans.pop(req.rid, None)
        if sp is None:
            return
        dec = sp.get("decode")
        if dec is not None and dec.open:
            self.tracer.end(dec, tokens=len(req.output))
        for k in ("queued", "prefill"):
            s = sp.get(k)
            if s is not None and s.open:
                self.tracer.end(s)
        if sp["request"].open:
            self.tracer.end(sp["request"], status=status,
                            tokens=len(req.output))

    def _sample_gauges(self, depth: int) -> None:
        """Mirror the point-in-time pool state into the obs gauges (one
        call per scheduling round; only reached when obs is enabled)."""
        mx = self._mx
        mx["queue_depth"].set(depth)
        mx["active"].set(sum(r is not None for r in self.engine.active))
        mx["prefilling"].set(len(self.engine.prefilling_slots()))
        kv = self.engine.kv_stats()
        if kv:
            for k in _KV_GAUGES:
                if k in kv:
                    mx[f"kv.{k}"].set(kv[k])

    def _stats_line(self, now: float) -> None:
        """One periodic info-level summary line through the structured
        logger (``stats_interval_s=``) — replaces ad-hoc caller lambdas."""
        s = self.stats()
        ttft_p50 = None if s["ttft_s"] is None else s["ttft_s"]["p50"]
        self.log.info(
            "stats",
            elapsed_s=round(now, 3),
            submitted=s["submitted"], completed=s["completed"],
            expired=s["expired"], rejected=s["rejected"],
            queued=s["queued"], active=s["active"],
            tokens=s["tokens"],
            tokens_per_s=(round(s["tokens_per_s"], 1)
                          if s["tokens_per_s"] is not None else None),
            ttft_p50_s=(round(ttft_p50, 4) if ttft_p50 is not None else None),
        )

    # -- observability ----------------------------------------------------

    def queue_depth_trace(self) -> list:
        """(elapsed_s, queue_depth) samples, one per scheduling round
        (capped tail: the most recent 4096 rounds)."""
        return list(self._depth_samples)

    def drain_finished(self) -> list:
        """Return and clear the finished list — long-lived callers should
        drain periodically so completed Request objects don't accumulate."""
        out, self.finished = self.finished, []
        return out

    def stats(self) -> dict:
        """Aggregate metrics snapshot (see docs/serving.md for the glossary).

        TTFT is measured from *arrival* (not admission), so queueing delay
        under load shows up where a caller would feel it; inter-token
        latencies are PER EMITTED TOKEN — the gaps between consecutive
        emitted tokens of one request, pooled over all requests (finished
        aggregates plus the currently active requests' partial streams).
        When a round emits n > 1 tokens of one request (speculative
        multi-token acceptance) the round's wall time spreads uniformly
        across them, so ITL keeps meaning seconds-per-token instead of
        deflating to seconds-per-round; ``tokens_per_round`` (mean tokens
        emitted per active slot per decode round — exactly 1.0 without
        speculation) carries the round-shape signal separately.
        ``tokens_per_s`` spans first admission to the last emitted token.
        TTFT/ITL percentiles are over the most recent 4096 samples.
        ``spec`` is None unless the engine speculates (``spec_decode=k``);
        ``accept_rate`` is accepted/drafted over greedy slots (sampled
        requests discard their drafts and are not counted).

        Every field is defined for every scheduler state: zero completed
        requests never divides by zero or emits NaN (``tokens_per_s`` is
        None until a span exists), and a workload where no request ever
        produced a first token — e.g. everything expired in the queue —
        reports ``ttft_s: None`` rather than an empty summary dict.
        """
        active_recs = list(self._rec.values())
        itls = list(self._itls) + [
            b - a for rec in active_recs
            for a, b in zip(rec["token_times"], rec["token_times"][1:])
        ]
        tokens = self._tokens_done + sum(
            len(rec["token_times"]) for rec in active_recs
        )
        span = 0.0
        if self._span_start is not None and self._span_end is not None:
            span = self._span_end - self._span_start
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "expired": self.expired,
            "rejected": self.rejected,
            "queued": len(self.queue),
            "active": sum(r is not None for r in self.engine.active),
            "prefilling": len(self.engine.prefilling_slots()),
            "decode_steps": self.decode_steps,
            "kv": self.engine.kv_stats(),
            "tokens": tokens,
            "tokens_per_s": (tokens / span) if span > 0 else None,
            "tokens_per_round": (self._round_tokens / self._round_slots
                                 if self._round_slots else None),
            "ttft_s": _summary(list(self._ttfts)) if self._ttfts else None,
            "itl_s": _summary(itls),
            "spec": (None if not getattr(self.engine, "spec_k", 0) else {
                "k": self.engine.spec_k,
                "rounds": self._spec_rounds,
                "drafted": self._spec_drafted,
                "accepted": self._spec_accepted,
                "accept_rate": (self._spec_accepted / self._spec_drafted
                                if self._spec_drafted else None),
                "draft_s": _summary(list(self._draft_s)),
                "verify_s": _summary(list(self._verify_s)),
            }),
            "queue_depth": {
                "samples": len(self._depth_samples),
                "rounds": self._depth_rounds,
                "max": self._depth_max,
                "mean": (self._depth_sum / self._depth_rounds
                         if self._depth_rounds else 0.0),
            },
            "elapsed_s": self.elapsed(),
        }
