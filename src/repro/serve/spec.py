"""Speculative decoding: a cheap refit KAN drafter + one-pass batched verify.

The paper's co-design premise is that ASP quantization + the fused spline
pipeline buy a *cheap approximate* datapath next to the exact one.  This
module cashes that in for serving latency: a **draft model** built from the
deployed target's own float weights via ``core.kan_layer.refit_layer_spec``
— reduced spline grid/order and/or lower ASP bits, optionally routed
through a cheaper runtime backend, NO retraining — proposes ``k`` greedy
tokens per active slot, and the target scores all ``k+1`` positions in ONE
batched forward (``models.model.verify_step``) through the existing paged
KV machinery.  The longest draft prefix matching the target's own greedy
argmax is accepted, so emitted streams are **bit-identical** to plain
decode: every emitted token is an argmax of the exact logits row the
sequential baseline would have produced (the verify pass is row-for-row
bit-identical to ``decode_step`` — see ``tests/test_spec_decode.py``).
The drafter only decides how MANY of those rows are consumed per round.

Layering: :class:`DraftSpec` describes the drafter's reduced deployment
point; :class:`DraftModel` owns the refit+quantized params, a small
contiguous per-slot KV cache, and the lockstep batched propose loop.  The
engine (``serve.engine``) owns the verify pass + KV rollback
(``kvpool.truncate``); the scheduler (``serve.scheduler``) owns the
propose -> verify -> accept/emit round shape and the accept-rate metrics.

KV bookkeeping invariant (mirrors the engine's): ``pos[slot]`` counts the
drafter-KV positions known to hold the TRUE token stream — positions
written with draft tokens that were later rejected are *behind* ``pos``
only until ``truncate`` rolls ``pos`` back over them; the next propose
round re-writes those rows with true tokens before any query can attend
them (scatter precedes gather in ``attention_decode``, and masked lanes
contribute exact zeros).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M
from .. import runtime
from ..obs.trace import profile_scope

__all__ = ["DraftSpec", "DraftModel"]


@dataclasses.dataclass(frozen=True)
class DraftSpec:
    """Deployment point of the drafter, relative to the target config.

    ``None`` fields inherit/derive from the target: ``grid`` halves the
    target's spline grid (the cheapest refit that keeps useful accept
    rates — KANtize-style low-G variants retain most accuracy), ``order``
    and ``n_bits`` inherit, ``backend`` inherits the engine's KAN backend
    resolution.  Parse the ``--draft-spec`` CLI form with :meth:`parse`:
    ``"grid=4,order=2,bits=6,backend=ref"`` (any subset of keys).
    """

    grid: int | None = None
    order: int | None = None
    n_bits: int | None = None
    backend: str | None = None

    _KEYS = {"grid": "grid", "order": "order", "bits": "n_bits",
             "n_bits": "n_bits", "backend": "backend"}

    @classmethod
    def parse(cls, s: str | None) -> "DraftSpec":
        if not s:
            return cls()
        kw = {}
        for part in s.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"bad --draft-spec entry {part!r} "
                                 f"(want key=value)")
            key, val = part.split("=", 1)
            field = cls._KEYS.get(key.strip())
            if field is None:
                raise ValueError(f"unknown --draft-spec key {key!r} "
                                 f"(known: grid, order, bits, backend)")
            kw[field] = val.strip() if field == "backend" else int(val)
        return cls(**kw)

    def resolve(self, cfg: ModelConfig) -> tuple:
        """(grid, order, n_bits) for the drafter given the target config."""
        grid = self.grid if self.grid is not None else max(2, cfg.kan_grid // 2)
        order = self.order if self.order is not None else cfg.kan_order
        n_bits = self.n_bits if self.n_bits is not None else cfg.kan_n_bits
        if grid < 1 or order < 1 or n_bits < 1:
            raise ValueError(f"draft spec fields must be >= 1, got "
                             f"grid={grid} order={order} bits={n_bits}")
        return grid, order, n_bits


def refit_kan_ffn_params_tree(params: dict, cfg: ModelConfig,
                              draft_cfg: ModelConfig) -> dict:
    """Refit every KAN-FFN block of a FLOAT param tree onto the drafter's
    (G, K) basis by least squares (``refit_layer_spec`` — the PR-3 grid
    transfer, no retraining).  Same group walk as
    ``quantize_kan_ffn_params_tree``; edge counts and the hidden width are
    unchanged (``draft_cfg`` must pin ``kan_d_hidden``), only the
    per-edge coefficient basis shrinks from G+K to G'+K' columns."""
    from ..core.kan_layer import refit_layer_spec
    from ..models.layers import kan_ffn_spec

    old_spec = kan_ffn_spec(cfg)
    new_spec = kan_ffn_spec(draft_cfg)

    def refit_ffn(ffn: dict) -> dict:
        l1 = refit_layer_spec({"c": ffn["c1"], "w_b": ffn["wb1"]},
                              old_spec, new_spec)
        l2 = refit_layer_spec({"c": ffn["c2"], "w_b": ffn["wb2"]},
                              old_spec, new_spec)
        return {"c1": l1["c"], "wb1": l1["w_b"],
                "c2": l2["c"], "wb2": l2["w_b"]}

    def refit_group(gp: dict) -> dict:
        out = dict(gp)
        for k, v in gp.items():
            if not k.endswith("_ffn"):
                continue
            repeats = v["c1"].shape[0]
            rs = [refit_ffn(jax.tree.map(lambda a: a[r], v))
                  for r in range(repeats)]
            out[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *rs)
        return out

    p = dict(params)
    for stack_key in ("decoder", "encoder"):
        if stack_key in p:
            p[stack_key] = [refit_group(g) for g in p[stack_key]]
    return p


class DraftModel:
    """The drafter: refit+quantized params + small per-slot KV state.

    Built from the target's FLOAT params (captured by the engine before its
    own quantization pass): every KAN-FFN block is refit onto the reduced
    (G, K) basis, then ASP-quantized at the drafter's bit width, and the
    result deploys through the SAME runtime plan cache as the target — its
    reduced specs key separate plan entries, so drafter and target never
    share (or retrace) each other's compiled pipelines.

    The KV state is a plain contiguous ``(slots, max_len)`` cache (drafter
    sequences are as long as the target's but the drafter is cheap — paging
    it would buy nothing and cost a second pool); ``pos[slot]`` tracks the
    true-token watermark per the module docstring.
    """

    def __init__(self, float_params, cfg: ModelConfig, spec: DraftSpec,
                 slots: int, max_len: int, kan_backend: str | None = None,
                 attn_backend: str | None = None, mesh=None):
        from ..core.kan_ffn_deploy import quantize_kan_ffn_params_tree
        from ..models.layers import kan_ffn_hidden

        if cfg.ffn_kind != "kan":
            raise ValueError("DraftModel requires a KAN-FFN target config")
        grid, order, n_bits = spec.resolve(cfg)
        # kan_d_hidden MUST be pinned: the default hidden-width rule divides
        # by G+K, which the drafter changes — the drafter must keep the
        # target's layer geometry (only the per-edge basis shrinks)
        self.cfg = dataclasses.replace(
            cfg, kan_grid=grid, kan_order=order, kan_n_bits=n_bits,
            kan_layer_bits=(),  # drafter is uniform: drop target's mixed bits
            kan_d_hidden=kan_ffn_hidden(cfg),
        )
        self.spec = spec
        self.kan_backend = (spec.backend if spec.backend is not None
                            else kan_backend)
        runtime.resolve_backend(self.kan_backend)  # validate eagerly
        self.attn_backend = runtime.resolve_attn_backend(attn_backend)
        self.slots = slots
        self.max_len = max_len
        self.mesh = mesh
        params = refit_kan_ffn_params_tree(float_params, cfg, self.cfg)
        params = quantize_kan_ffn_params_tree(params, self.cfg)
        if mesh is not None:
            from ..dist.sharding import (cache_pspecs, param_pspecs,
                                         to_shardings)

            params = jax.device_put(
                params, to_shardings(param_pspecs(params, mesh), mesh))
        self.params = params
        self.cache = M.init_cache(params, self.cfg, slots, max_len)
        if mesh is not None:
            self.cache = jax.device_put(
                self.cache,
                to_shardings(cache_pspecs(self.cache, mesh, slots), mesh))
        self.pos = np.zeros(slots, np.int32)
        self.decode_traces = 0
        self.prefill_traces = 0

        dcfg = self.cfg
        drf = self

        @functools.partial(jax.jit, static_argnames=("attn_backend",))
        def _decode(params, cache, token, pos, attn_backend):
            drf.decode_traces += 1  # python body runs only while tracing
            with runtime.use_attn_backend(attn_backend):
                return M.decode_step(params, cache, token, pos, dcfg)

        self._decode = functools.partial(_decode,
                                         attn_backend=self.attn_backend)

        @functools.partial(jax.jit, static_argnames=("attn_backend",))
        def _prefill_one(params, tokens, last_index, attn_backend):
            drf.prefill_traces += 1
            with runtime.use_attn_backend(attn_backend):
                return M.prefill(params, {"tokens": tokens}, dcfg,
                                 max_len=max_len, last_index=last_index)

        self._prefill = functools.partial(_prefill_one,
                                          attn_backend=self.attn_backend)

    # -- per-slot lifecycle ------------------------------------------------

    def prefill_slot(self, slot: int, req) -> None:
        """Prefill ``req``'s prompt into the drafter's cache row for
        ``slot`` (B=1, power-of-two length bucket like the engine's
        contiguous prefill; pad KV is zeroed out of the splice)."""
        plen = len(req.prompt)
        prompt = list(req.prompt)
        lb = runtime.bucket_batch(plen)
        if plen < lb <= self.max_len - 1:
            prompt = prompt + [0] * (lb - plen)
        tokens = jnp.asarray([prompt], jnp.int32)
        with runtime.use_backend(self.kan_backend), \
                runtime.use_mesh(self.mesh), \
                profile_scope("serve.draft_prefill"):
            _, cache1 = self._prefill(
                self.params, tokens, jnp.asarray([plen - 1], jnp.int32))
        tmask = jnp.arange(self.max_len) < plen

        def splice(pool, one):
            one = one[:, 0]                      # (repeats, T, H, D)
            if one.ndim >= 2 and one.shape[1] == self.max_len:
                one = jnp.where(
                    tmask.reshape((1, -1) + (1,) * (one.ndim - 2)), one, 0)
            return pool.at[:, slot].set(one)

        self.cache = jax.tree.map(splice, self.cache, cache1)
        self.pos[slot] = plen

    def truncate(self, slot: int, new_len: int) -> None:
        """Roll the slot's true-token watermark back to ``new_len`` after a
        verify round rejected draft positions (rejected rows are re-written
        by the next propose before anything can attend them)."""
        self.pos[slot] = min(int(self.pos[slot]), int(new_len))

    def release(self, slot: int) -> None:
        self.pos[slot] = 0

    # -- propose -----------------------------------------------------------

    def propose(self, pend: dict, k: int) -> dict:
        """Draft ``k`` greedy tokens for every slot in ``pend``.

        ``pend[slot]`` is that slot's catch-up token list: the true tokens
        at drafter positions ``pos[slot] .. engine_pos`` inclusive — at
        steady state just the last emitted token (one entry); after a
        fully-accepted round, two (the drafter never saw the final accepted
        draft's KV row).  All slots advance in LOCKSTEP through one batched
        single-token decode per step: slot ``i`` feeds ``pend[i]`` first,
        then chains its own argmax, for ``max_len(pend) - 1 + k`` steps.
        Slots needing fewer steps keep chaining past ``k`` (their extra KV
        rows are rolled back by ``truncate``); slots not in ``pend`` ride
        along feeding token 0 (their rows are dead: either scratch state a
        future prefill overwrites, or positions past a retired stream).

        Returns ``{slot: [k draft token ids]}``.  After this call
        ``pos[slot]`` assumes all k drafts verify (``engine_pos + k``); the
        caller MUST follow up with :meth:`truncate` to the accepted length.
        """
        if k < 1:
            raise ValueError(f"propose needs k >= 1, got {k}")
        if not pend:
            return {}
        queues = {i: list(toks) for i, toks in pend.items()}
        for i, q in queues.items():
            if not q:
                raise ValueError(f"slot {i}: empty pend (drafter ahead of "
                                 f"engine?)")
        nsteps = max(len(q) for q in queues.values()) - 1 + k
        drafts = {i: [] for i in queues}
        chain = np.zeros(self.slots, np.int32)   # last argmax per slot
        pos = self.pos.copy()
        with runtime.use_backend(self.kan_backend), \
                runtime.use_mesh(self.mesh), \
                profile_scope("serve.draft", steps=nsteps):
            for step in range(nsteps):
                feed = np.zeros(self.slots, np.int32)
                for i, q in queues.items():
                    feed[i] = q[step] if step < len(q) else chain[i]
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(feed),
                    jnp.asarray(pos))
                nxt = np.asarray(jnp.argmax(logits, axis=-1))
                pos += 1
                for i, q in queues.items():
                    chain[i] = nxt[i]
                    if step >= len(q) - 1 and len(drafts[i]) < k:
                        drafts[i].append(int(nxt[i]))
        for i, q in queues.items():
            # rows written through engine_pos + k - 1; next valid write at:
            self.pos[i] = int(self.pos[i]) + len(q) - 1 + k
        return drafts

    # -- observability -----------------------------------------------------

    def describe(self) -> dict:
        from ..core.kan_layer import KANSpec, param_count
        from ..models.layers import kan_ffn_hidden

        def ffn_params(c: ModelConfig) -> int:
            dims = (c.d_model, kan_ffn_hidden(c), c.d_model)
            return param_count(KANSpec(dims=dims, grid_size=c.kan_grid,
                                       order=c.kan_order))

        base = self.cfg  # target fields live on the engine; report ours
        return {
            "kan_grid": base.kan_grid,
            "kan_order": base.kan_order,
            "kan_n_bits": base.kan_n_bits,
            "kan_backend": self.kan_backend,
            "attn_backend": self.attn_backend,
            "ffn_params_per_block": ffn_params(base),
            "decode_traces": self.decode_traces,
            "prefill_traces": self.prefill_traces,
        }
