"""Paged KV-cache pool: block allocator + hash-keyed prefix cache.

The PR-5 engine stored one contiguous ``(slots, max_len)`` KV slab per
attention layer and re-prefilled every prompt from scratch.  This module
owns the *bookkeeping* half of the paged replacement:

  * **blocks** — KV storage is cut into fixed-size blocks of
    ``block_size`` tokens (a multiple of the flash kernel's KV tile
    granularity, so a block never straddles a kernel tile).  The device
    arrays live in the engine's cache pytree with a leading
    ``num_blocks`` dim; this class hands out *block ids* into that dim.
  * **free-list allocator** — O(1) allocate/free with per-block
    refcounts.  Block id 0 is reserved as the *scratch* block: freed
    slots' table rows point at it so a retired slot's in-flight decode
    write can never corrupt a live block, and it is never handed out.
  * **hash-keyed prefix cache** — prompt token chunks are chain-hashed
    per block (``h_i = H(h_{i-1}, tokens[i*bs:(i+1)*bs])``), and FULL
    prompt blocks are published under their chain hash when a prefill
    completes.  A later request with the same prefix splices the cached
    blocks into its block table copy-free and starts prefill after them.
    Only full blocks are ever shared, and shared blocks are never
    written again (decode writes land at ``pos >= cached_len``, always
    in blocks the request owns exclusively), so no copy-on-write is
    needed.
  * **eviction** — a cached block whose refcount drops to zero becomes
    *evictable* (it stays in the hash map so it can still be reused for
    free).  When the free list runs dry, the least-recently-used
    evictable block is unpublished and recycled.

The pool is pure host-side state — it never touches device memory — so
every method is cheap enough for the scheduler's admit path.
"""

from __future__ import annotations

import collections

__all__ = ["KVBlockPool", "KVPoolExhausted", "hash_token_blocks"]

SCRATCH_BLOCK = 0  # reserved: write-dump for retired slots, never allocated


class KVPoolExhausted(RuntimeError):
    """Raised when an allocation finds no free and no evictable block."""


def hash_token_blocks(tokens, block_size: int) -> list:
    """Chain hashes of the FULL ``block_size`` chunks of a token list.

    ``out[i]`` identifies tokens ``[0 : (i+1) * block_size)`` — each hash
    folds in the previous one, so a match at chunk i implies the whole
    prefix up to i matches.  Deterministic within a process (the cache is
    in-process state); the trailing partial chunk is never hashed because
    only full blocks are shareable.
    """
    out, h = [], 0x9E3779B9
    for i in range(len(tokens) // block_size):
        chunk = tuple(tokens[i * block_size:(i + 1) * block_size])
        h = hash((h, chunk))
        out.append(h)
    return out


class KVBlockPool:
    """Free-list block allocator with refcounts and a prefix cache.

    ``num_blocks`` counts the scratch block; ``num_blocks - 1`` ids are
    allocatable.  ``prefix_cache=False`` degrades to a plain allocator
    (every ``match_prefix`` misses, nothing is published).
    """

    def __init__(self, num_blocks: int, block_size: int,
                 prefix_cache: bool = True):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 is scratch), got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self._free: collections.deque = collections.deque(
            range(1, num_blocks))
        self._ref = [0] * num_blocks
        self._hash_to_block: dict = {}          # chain hash -> block id
        self._block_hash: dict = {}             # block id -> chain hash
        # cached blocks with refcount 0, in LRU order (oldest first)
        self._evictable: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0          # prefix-cache block hits
        self.misses = 0        # prompt blocks that had to prefill
        self.allocs = 0
        self.evictions = 0
        self.truncations = 0   # tail blocks released by truncate()
        self._live = 0         # blocks with refcount > 0
        self.peak_in_use = 0

    # -- allocation -------------------------------------------------------

    def alloc(self) -> int:
        """Take a block (refcount 1); evicts the LRU cached block if the
        free list is empty.  Raises :class:`KVPoolExhausted` otherwise."""
        if self._free:
            bid = self._free.popleft()
        elif self._evictable:
            bid, _ = self._evictable.popitem(last=False)
            self._unpublish(bid)
            self.evictions += 1
        else:
            raise KVPoolExhausted(
                f"KV pool exhausted: all {self.num_blocks - 1} blocks "
                f"referenced (no evictable prefix-cache blocks); grow the "
                f"pool (kv_blocks=) or reduce slots x max_len"
            )
        self._ref[bid] = 1
        self.allocs += 1
        self._live += 1
        self.peak_in_use = max(self.peak_in_use, self._live)
        return bid

    def retain(self, bid: int) -> None:
        if bid == SCRATCH_BLOCK:
            raise ValueError("cannot retain the scratch block")
        if self._ref[bid] == 0:
            # reviving a cached, evictable block (prefix hit)
            self._evictable.pop(bid, None)
            self._live += 1
            self.peak_in_use = max(self.peak_in_use, self._live)
        self._ref[bid] += 1

    def release(self, bid: int) -> None:
        if self._ref[bid] <= 0:
            raise ValueError(f"release of unreferenced block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._live -= 1
            if bid in self._block_hash:
                # keep the KV around for future prefix hits; reclaimable
                self._evictable[bid] = True
                self._evictable.move_to_end(bid)
            else:
                self._free.append(bid)

    def truncate(self, block_ids: list, new_len: int) -> list:
        """Shrink a request's block chain to cover ``new_len`` tokens.

        Releases every WHOLE tail block past ``ceil(new_len / block_size)``
        (speculative decode rolls back rejected draft positions this way —
        partially-filled tail rows need no release, the next write simply
        overwrites them before any query can attend them).  ``block_ids``
        is truncated in place; the released ids are returned so the caller
        can reset its device block-table rows.

        Consistency guard: a cached (published) prefix block can never be
        a truncation victim — shared prefix KV is immutable by
        construction, and speculative tails always start at or after the
        prompt end.  Hitting one means the caller's accounting is wrong,
        so it raises instead of corrupting the prefix cache.
        """
        if new_len < 0:
            raise ValueError(f"new_len must be >= 0, got {new_len}")
        keep = -(-new_len // self.block_size)  # ceil
        tail = block_ids[keep:]
        for bid in tail:
            if bid in self._block_hash:
                raise ValueError(
                    f"truncate would release cached prefix block {bid}; "
                    f"published blocks are immutable (new_len={new_len})")
            self.release(bid)
        self.truncations += len(tail)
        del block_ids[keep:]
        return tail

    # -- prefix cache -----------------------------------------------------

    def match_prefix(self, tokens, max_tokens: int | None = None) -> list:
        """Longest cached block chain for ``tokens``; retains every hit.

        Returns the block ids covering ``len(result) * block_size`` prompt
        tokens.  ``max_tokens`` caps the usable prefix (the engine passes
        ``len(prompt) - 1`` so at least one real token is always left to
        prefill — the first-token logits must come from somewhere).
        Counts hits/misses over the prompt's full blocks.
        """
        limit = len(tokens) if max_tokens is None else min(
            len(tokens), max_tokens)
        n_full = len(tokens) // self.block_size
        out = []
        if self.prefix_cache:
            for h in hash_token_blocks(tokens, self.block_size):
                if len(out) + 1 > limit // self.block_size:
                    break
                bid = self._hash_to_block.get(h)
                if bid is None:
                    break
                self.retain(bid)
                out.append(bid)
        self.hits += len(out)
        self.misses += n_full - len(out)
        return out

    def publish_prefix(self, tokens, block_ids) -> None:
        """Publish a prompt's FULL blocks under their chain hashes.

        ``block_ids[i]`` must hold the KV of tokens
        ``[i*bs : (i+1)*bs]``.  Idempotent for already-published hashes
        (the existing entry wins — both blocks hold identical KV, and the
        older one is the one other requests may already reference).
        """
        if not self.prefix_cache:
            return
        for h, bid in zip(hash_token_blocks(tokens, self.block_size),
                          block_ids):
            if h in self._hash_to_block:
                continue
            if bid in self._block_hash:  # block already published (cached hit)
                continue
            self._hash_to_block[h] = bid
            self._block_hash[bid] = h

    def _unpublish(self, bid: int) -> None:
        h = self._block_hash.pop(bid, None)
        if h is not None:
            self._hash_to_block.pop(h, None)

    # -- observability ----------------------------------------------------

    def blocks_in_use(self) -> int:
        """Blocks with a live reference (excludes evictable cached ones)."""
        return sum(1 for r in self._ref[1:] if r > 0)

    def blocks_cached(self) -> int:
        """Published blocks kept only for future prefix hits (refcount 0)."""
        return len(self._evictable)

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return (self.hits / total) if total else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = self.allocs = self.evictions = 0
        self.truncations = 0
        self.peak_in_use = self._live

    def stats(self) -> dict:
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "prefix_cache": self.prefix_cache,
            "blocks_in_use": self.blocks_in_use(),
            "blocks_in_use_peak": self.peak_in_use,
            "blocks_cached": self.blocks_cached(),
            "blocks_free": len(self._free),
            "prefix_hits": self.hits,
            "prefix_misses": self.misses,
            "prefix_hit_rate": self.hit_rate(),
            "allocs": self.allocs,
            "evictions": self.evictions,
            "truncations": self.truncations,
        }

    def check_consistent(self) -> None:
        """Invariant check for tests: every allocatable block is in exactly
        one of {free, referenced, evictable}, and the hash maps mirror."""
        free = set(self._free)
        ref = {b for b in range(1, self.num_blocks) if self._ref[b] > 0}
        evict = set(self._evictable)
        assert not (free & ref), (free, ref)
        assert not (free & evict), (free, evict)
        assert not (ref & evict), (ref, evict)
        assert free | ref | evict == set(range(1, self.num_blocks)), (
            free, ref, evict)
        assert self._ref[SCRATCH_BLOCK] == 0
        assert self._live == len(ref), (self._live, ref)
        for h, bid in self._hash_to_block.items():
            assert self._block_hash.get(bid) == h, (h, bid)
        for bid, h in self._block_hash.items():
            assert self._hash_to_block.get(h) == bid, (h, bid)
        for bid in self._evictable:
            assert bid in self._block_hash, bid
