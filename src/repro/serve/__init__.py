"""Serving layer: slot-based engine + async streaming scheduler.

:mod:`repro.serve.engine` owns the state (slot pool, KV cache, compiled
prefill/decode); :mod:`repro.serve.scheduler` owns the event loop
(arrivals, admission/backpressure, deadlines, streaming callbacks, seeded
sampling, TTFT/throughput metrics); :mod:`repro.serve.spec` owns the
speculative-decode drafter (refit KAN draft model + k-token propose).
See ``docs/serving.md``.
"""

from .engine import Request, ServeEngine, prefill_bucketing_supported
from .scheduler import (
    ManualClock,
    QueueFull,
    SamplingParams,
    Scheduler,
    sample_token,
)
from .spec import DraftModel, DraftSpec

__all__ = [
    "DraftModel",
    "DraftSpec",
    "ManualClock",
    "QueueFull",
    "Request",
    "SamplingParams",
    "Scheduler",
    "ServeEngine",
    "prefill_bucketing_supported",
    "sample_token",
]
