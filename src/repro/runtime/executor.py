"""Executor protocol + registry: the single dispatch point for KAN inference.

Every execution surface (``kan_network_apply(quantized=True)``,
``kan_ffn_apply_quantized``, ``ServeEngine(kan_deploy=True)``,
``launch.serve``) resolves its backend here instead of carrying its own
``backend=`` strings and ``default_interpret()`` probes.  Three registered
backends run the same deployed bundle (duck-typed: ``.dims``, ``.specs``,
``.layers`` (padded {"lut","wc","wb"}, or the int4-packed
{"lut"[,"lutp"],"wcp","wscale","wb"} form for <=4-bit layers),
``.residual_raw``):

  * ``"ref"``    — the layered jnp composition (moved here from
                   ``kan_network_apply_ref``): per-layer SH-LUT dense basis,
                   banded matmul, tanh-rescale + re-quantize boundary.  The
                   bit-exactness oracle for the other two.
  * ``"pallas"`` — the fused multi-layer Pallas pipeline
                   (``kernels.kan_spline.pipeline``), int codes across layer
                   boundaries, one jit per (geometry, bucket).
  * ``"acim"``   — the fused pipeline with the paper's RRAM-ACIM
                   non-idealities injected at the banded-MAC contraction:
                   TM-DV input-generator noise on the entry codes
                   (:func:`repro.core.tmdv.apply_input_noise`), systematic
                   IR-drop attenuation of the conductance rows, and the
                   per-array partial-sum sigma folded into each output tile
                   — all seeded by an explicit PRNG key, so runs reproduce.

Backend selection precedence: explicit argument > :func:`use_backend` scope
> ``REPRO_KAN_BACKEND`` env var > the call site's default.  All backends
share the :mod:`plancache` (batch bucketing + LRU of compiled applies).

Every backend also has a MESH dimension (:mod:`repro.runtime.meshexec`):
when a mesh is bound (explicit ``mesh=`` argument > :func:`use_mesh` scope >
the bundle's ``DeployedKAN.placement``), the cached apply is built as a
``shard_map`` — batch over ``"data"``, each layer's output channels over
``"model"`` per ``dist.sharding.deployed_kan_pspecs``, the boundary
requantizer shard-local and the int boundary codes all-gathered between
layers.  The plan-cache key carries the mesh fingerprint, so sharded and
unsharded entries never collide.
"""

from __future__ import annotations

import collections
import contextlib
import contextvars
import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import REGISTRY as _OBS_REGISTRY
from ..obs.trace import profile_scope
from ..core.asp_quant import dense_basis_from_codes, quantize_input
from ..core.cim import CIMConfig
from ..core.tmdv import TMDVConfig, apply_input_noise
from ..kernels.kan_spline.pipeline import (
    kan_pipeline_impl,
    run_pipeline_layer,
    shard_local_plan,
    unpacked_wc,
    weight_bits,
)
from .meshexec import (
    build_sharded_runner,
    mesh_axis_sizes,
    mesh_fingerprint,
    mesh_from_fingerprint,
    register_mesh,
    resolve_mesh,
    use_mesh,
)
from .plancache import PLAN_CACHE, PlanKey, bucket_batch

__all__ = [
    "ENV_BACKEND_VAR",
    "default_interpret",
    "dispatch_counts",
    "reset_dispatch_counts",
    "register_executor",
    "available_backends",
    "resolve_backend",
    "get_executor",
    "use_backend",
    "use_mesh",
    "resolve_mesh",
    "quiet_cim_config",
    "RefExecutor",
    "PallasExecutor",
    "ACIMExecutor",
]

ENV_BACKEND_VAR = "REPRO_KAN_BACKEND"

# Per-backend dispatch counts (host-side calls through _CachedExecutor):
# always on — one dict increment per KAN execution — so the benchmark legs
# can report them without enabling the obs registry; obs pulls them at
# snapshot time as ``runtime.backend_dispatch{backend=...}``.
DISPATCH_COUNTS: collections.Counter = collections.Counter()


def dispatch_counts() -> dict:
    """Snapshot of per-backend KAN dispatch counts since process start (or
    the last :func:`reset_dispatch_counts`)."""
    return dict(DISPATCH_COUNTS)


def reset_dispatch_counts() -> None:
    DISPATCH_COUNTS.clear()


def default_interpret() -> bool:
    """Pallas kernels need interpret mode off-TPU (CPU containers, CI)."""
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------------
# Registry + resolution
# ----------------------------------------------------------------------------

_EXECUTORS: dict = {}
# innermost use_backend() override; a ContextVar so concurrent engines on
# different threads (or async tasks) cannot clobber each other's scope
_SCOPE_BACKEND: contextvars.ContextVar = contextvars.ContextVar(
    "repro_kan_backend_scope", default=None
)


def register_executor(name: str, executor) -> None:
    _EXECUTORS[name] = executor


def available_backends() -> tuple:
    return tuple(sorted(_EXECUTORS))


def resolve_backend(backend: str | None = None, *,
                    default: str = "pallas") -> str:
    """Resolve a backend name; raises ValueError for unknown names."""
    if backend is None or backend == "auto":
        backend = _SCOPE_BACKEND.get()
    if backend is None:
        backend = os.environ.get(ENV_BACKEND_VAR, "").strip() or None
    if backend is None:
        backend = default
    if backend not in _EXECUTORS:
        raise ValueError(
            f"unknown backend {backend!r}; registered: {available_backends()}"
        )
    return backend


def get_executor(backend: str | None = None, *, default: str = "pallas"):
    return _EXECUTORS[resolve_backend(backend, default=default)]


@contextlib.contextmanager
def use_backend(backend: str | None):
    """Scoped backend override (beats the env var, loses to explicit args).

    ``None`` is a no-op passthrough so callers can plumb an optional choice.
    """
    if backend is not None and backend not in _EXECUTORS:
        raise ValueError(
            f"unknown backend {backend!r}; registered: {available_backends()}"
        )
    token = _SCOPE_BACKEND.set(
        backend if backend is not None else _SCOPE_BACKEND.get()
    )
    try:
        yield
    finally:
        _SCOPE_BACKEND.reset(token)


# ----------------------------------------------------------------------------
# Shared helpers
# ----------------------------------------------------------------------------


def _entry_codes(dep, x, xraw):
    """Entry coding, identical across backends (and to the PR-1 contract):
    KAN stacks quantize x directly; FFN stacks (residual_raw) quantize
    tanh(x) and keep the raw activation for the ReLU branch."""
    spec0 = dep.specs[0]
    if dep.residual_raw:
        xraw = x.astype(jnp.float32) if xraw is None else xraw
        codes = quantize_input(jnp.tanh(xraw), spec0)
    else:
        codes = quantize_input(x, spec0)
        xraw = None
    return codes, xraw


def _logical_layer(lw: dict, lp) -> tuple:
    """Slice one padded deployed layer back to its logical (lut, wc, wb).

    int4-packed layers decode through ``unpacked_wc`` first — the same
    nibble-extract + f32 scale product the kernel computes in-lane, so the
    ref composition stays the bit-exactness oracle for packed layers too.
    """
    nb = lp.spec.num_basis
    wc = unpacked_wc(lw, lp).reshape(lp.fp, nb, lp.op)[: lp.f, :, : lp.o]
    wb = lw["wb"][: lp.f, : lp.o]
    return lw["lut"], wc, wb


def _pad_batch(a, bucket):
    if a is None:
        return None
    return jnp.pad(a, ((0, bucket - a.shape[0]),) + ((0, 0),) * (a.ndim - 1))


def _slice_result(out, b, return_intermediates):
    if return_intermediates:
        y, codes = out
        return y[:b], tuple(c[:b] for c in codes)
    return out[:b]


# Deriving a mesh fingerprint walks every device of the mesh and the plan's
# layer geometry; on the serving hot path that would run per token per
# FFN block just to hit an already-cached entry, so the derivation is
# memoized on (mesh, geometry, bucket).  The registration side effects stay
# per-call (cheap dict writes) so reset_cache()/reset_shard_notes() are
# repopulated by the very next execution.
_MESH_FP_MEMO: dict = {}


def _mesh_key_fingerprint(mesh, dsize, msize, dims, specs, bucket,
                          residual_raw) -> tuple:
    memo_key = (mesh, dims, specs, bucket, residual_raw)
    hit = _MESH_FP_MEMO.get(memo_key)
    if hit is None:
        base = PLAN_CACHE.plan(bucket // dsize, dims, specs,
                               residual_raw=residual_raw)
        _, sharded, notes = shard_local_plan(base, msize)
        hit = (mesh_fingerprint(mesh, sharded), notes)
        if len(_MESH_FP_MEMO) > 256:
            _MESH_FP_MEMO.clear()
        _MESH_FP_MEMO[memo_key] = hit
    fp, notes = hit
    register_mesh(fp, mesh, notes)
    return fp


class _CachedExecutor:
    """Common plancache plumbing: bucket, pad, look up, run, slice.

    Subclasses customize via hooks: ``_flags(**opts)`` (backend statics that
    belong in the cache key), ``_build(plan_key)`` (the per-entry jitted
    apply), ``_run(...)`` (how the apply is invoked), and — for the mesh
    path — ``_mesh_layer_fn`` / ``_mesh_noise_fn`` (the per-shard layer step
    and the per-shard stochastic terms inside the shard_map body).
    """

    name = "?"

    def _flags(self, **opts) -> tuple:
        return ()

    def __call__(self, dep, x, *, xraw=None, interpret=None, key=None,
                 mesh=None, return_intermediates=False, **opts):
        if interpret is None:
            interpret = default_interpret()
        mesh = resolve_mesh(mesh, getattr(dep, "placement", None))
        codes, xraw = _entry_codes(dep, x, xraw)
        b = codes.shape[0]
        if mesh is None:
            bucket = bucket_batch(b)
            mesh_fp = ()
        else:
            dsize, msize = mesh_axis_sizes(mesh)
            # mesh-aware bucketing: the global bucket must split into
            # per-shard slabs of at least one batch tile (>= 8 rows), so the
            # bucket ladder starts at 8 * data_size (divisible by data_size
            # for ANY axis size — data sharding never needs a fallback)
            bucket = bucket_batch(b, lo=8 * dsize)
            mesh_fp = _mesh_key_fingerprint(
                mesh, dsize, msize, tuple(dep.dims), tuple(dep.specs),
                bucket, dep.residual_raw,
            )
        plan_key = PlanKey(
            dims=tuple(dep.dims),
            specs=tuple(dep.specs),
            bucket=bucket,
            residual_raw=dep.residual_raw,
            interpret=interpret,
            backend=self.name,
            flags=self._flags(**opts),
            mesh=mesh_fp,
        )
        _, apply = PLAN_CACHE.get(plan_key, self._build)
        DISPATCH_COUNTS[self.name] += 1
        with profile_scope(f"kan_spline.{self.name}"):
            out = self._run(apply, _pad_batch(codes, bucket),
                            _pad_batch(xraw, bucket), dep.layers, key,
                            return_intermediates)
        return _slice_result(out, b, return_intermediates)

    def _run(self, apply, codes, xraw, layers, key, return_intermediates):
        return apply(codes, xraw, layers,
                     return_intermediates=return_intermediates)

    def _build(self, key: PlanKey):
        if key.mesh:
            return self._build_sharded(key)
        return self._build_local(key)

    def _build_local(self, key: PlanKey):
        raise NotImplementedError

    # -- the mesh path ---------------------------------------------------

    def _mesh_layer_fn(self, key: PlanKey, local_plan):
        """Per-shard layer step: the fused Pallas kernel on local geometry
        (shared by "pallas" and "acim"; "ref" overrides with its jnp step)."""
        def layer_fn(li, lp, lw, h_codes, h_raw, psum_noise):
            return run_pipeline_layer(
                h_codes, h_raw if lp.residual_raw else None,
                lw, lp, local_plan.bp,
                interpret=key.interpret, psum_noise=psum_noise,
            )
        return layer_fn

    def _mesh_noise_fn(self, key: PlanKey, base_plan, local_plan, sharded):
        return None  # deterministic backends need no per-shard terms

    def _build_sharded(self, key: PlanKey):
        """One shard_mapped apply per (geometry, bucket, mesh fingerprint).

        The per-shard plan divides each sharded layer's padded output dim by
        the model-axis size (whole-column ownership: the MAC never reduces
        across shards) and rebuilds the batch tiling for the per-shard batch
        slab; tuned tile overrides are picked up through the plan cache at
        the per-shard geometry and kept wherever they still divide it.
        """
        mesh = mesh_from_fingerprint(key.mesh)
        dsize, _ = mesh_axis_sizes(mesh)
        base = PLAN_CACHE.plan(key.bucket // dsize, key.dims, key.specs,
                               residual_raw=key.residual_raw)
        local_plan, sharded, _ = shard_local_plan(base, mesh_axis_sizes(mesh)[1])
        assert sharded == key.mesh[3], (sharded, key.mesh)
        runner = build_sharded_runner(
            mesh,
            local_plan=local_plan,
            layer_sharded=sharded,
            residual_raw=key.residual_raw,
            layer_fn=self._mesh_layer_fn(key, local_plan),
            noise_fn=self._mesh_noise_fn(key, base, local_plan, sharded),
        )
        lp0 = base.layers[0]
        logical_o = tuple(lp.o for lp in base.layers)

        @functools.partial(jax.jit, static_argnames=("return_intermediates",))
        def apply(codes, xraw, layers, *extra, return_intermediates=False):
            PLAN_CACHE.record_trace()
            codes = jnp.pad(codes, ((0, 0), (0, lp0.fp - lp0.f)))
            if key.residual_raw:
                xraw = jnp.pad(
                    xraw.astype(jnp.float32), ((0, 0), (0, lp0.fp - lp0.f))
                )
            y, boundary = runner(codes, xraw, layers, *extra)
            y = y[:, : logical_o[-1]]
            if return_intermediates:
                return y, tuple(
                    c[:, : logical_o[li]] for li, c in enumerate(boundary)
                )
            return y

        return base, apply


# ----------------------------------------------------------------------------
# "ref": the layered jnp composition
# ----------------------------------------------------------------------------


def ref_composition(logical_layers, specs, codes, xraw, *,
                    residual_raw: bool, return_intermediates: bool = False):
    """Layered quantized composition over logical (lut, wc, wb) triples.

    Bit-identical to the PR-1 ``kan_layer_apply_quantized`` + tanh-rescale
    chain (same op order, same constants) — the oracle the Pallas pipeline's
    boundary codes are asserted against.
    """
    n = len(logical_layers)
    boundary = []
    y = None
    for li, (lut, wc, wb) in enumerate(logical_layers):
        spec = specs[li]
        basis = dense_basis_from_codes(codes, lut, spec)
        b = codes.shape[0]
        f, nb, o = wc.shape
        y = basis.reshape(b, f * nb) @ wc.reshape(f * nb, o)
        if residual_raw:
            resid = jax.nn.relu(xraw)
        else:
            resid = jax.nn.relu(
                spec.lo + codes.astype(jnp.float32) * spec.code_step
            )
        y = y + resid @ wb
        if li < n - 1:
            nxt = specs[li + 1]
            if residual_raw:
                xraw = y
                codes = quantize_input(jnp.tanh(y), nxt)
            else:
                h = jnp.tanh(y) * (0.5 * (nxt.hi - nxt.lo)) \
                    + 0.5 * (nxt.hi + nxt.lo)
                codes = quantize_input(h, nxt)
            boundary.append(codes)
    if return_intermediates:
        return y, tuple(boundary)
    return y


def _ref_padded_layer(lp, lw, codes, xraw, psum_noise=None):
    """One layer of the ref composition on PADDED per-shard geometry.

    The mesh path's jnp analogue of ``run_pipeline_layer``: same op order as
    the kernel (dense SH-LUT basis -> banded MAC -> fused ReLU branch ->
    kernel-style boundary re-code), operating on the padded weights a shard
    actually holds (zero-padded lanes contribute nothing).
    """
    spec = lp.spec
    b = codes.shape[0]
    basis = dense_basis_from_codes(codes, lw["lut"], spec)
    y = jax.lax.dot_general(
        basis.reshape(b, lp.fp * spec.num_basis),
        unpacked_wc(lw, lp),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if lp.residual_raw:
        resid = xraw.astype(jnp.float32)
    else:
        resid = spec.lo + codes.astype(jnp.float32) * spec.code_step
    y = y + jnp.maximum(resid, 0.0) @ lw["wb"].astype(jnp.float32)
    if psum_noise is not None:
        y = y + psum_noise
    if not lp.emit_codes:
        return y, None
    nxt = lp.next_spec
    h = jnp.tanh(y) * (0.5 * (nxt.hi - nxt.lo)) + 0.5 * (nxt.hi + nxt.lo)
    q = jnp.floor((h - nxt.lo) / nxt.code_step + 0.5).astype(jnp.int32)
    return y, jnp.clip(q, 0, nxt.num_codes - 1)


class RefExecutor(_CachedExecutor):
    name = "ref"

    def _mesh_layer_fn(self, key: PlanKey, local_plan):
        def layer_fn(li, lp, lw, h_codes, h_raw, psum_noise):
            return _ref_padded_layer(
                lp, lw, h_codes, h_raw if lp.residual_raw else None,
                psum_noise=psum_noise,
            )
        return layer_fn

    def _build_local(self, key: PlanKey):
        plan = PLAN_CACHE.plan(key.bucket, key.dims, key.specs,
                               residual_raw=key.residual_raw)

        @functools.partial(jax.jit, static_argnames=("return_intermediates",))
        def apply(codes, xraw, layers, return_intermediates=False):
            PLAN_CACHE.record_trace()
            logical = [_logical_layer(lw, lp)
                       for lw, lp in zip(layers, plan.layers)]
            return ref_composition(
                logical, key.specs, codes, xraw,
                residual_raw=key.residual_raw,
                return_intermediates=return_intermediates,
            )

        return plan, apply


# ----------------------------------------------------------------------------
# "pallas": the fused pipeline
# ----------------------------------------------------------------------------


class PallasExecutor(_CachedExecutor):
    name = "pallas"

    def _build_local(self, key: PlanKey):
        plan = PLAN_CACHE.plan(key.bucket, key.dims, key.specs,
                               residual_raw=key.residual_raw)

        @functools.partial(jax.jit, static_argnames=("return_intermediates",))
        def apply(codes, xraw, layers, return_intermediates=False):
            PLAN_CACHE.record_trace()
            return kan_pipeline_impl(
                codes, xraw, layers, plan,
                interpret=key.interpret,
                return_intermediates=return_intermediates,
            )

        return plan, apply


# ----------------------------------------------------------------------------
# "acim": the fused pipeline + RRAM-ACIM non-idealities
# ----------------------------------------------------------------------------


def quiet_cim_config() -> CIMConfig:
    """A CIMConfig with every non-ideality zeroed (bit-exact vs "pallas")."""
    return CIMConfig(
        ir_gamma=0.0,
        sigma_ps_ref=0.0,
        input_gen=TMDVConfig(sigma_v_ref=0.0, sigma_t=0.0),
    )


def _irdrop_row_gain(lp, cfg: CIMConfig, perm=None) -> np.ndarray | None:
    """Static per-row conductance gain (Fp*NB, 1), or None when IR-drop is off.

    Mirrors ``core.cim.cim_matmul``'s systematic term at typical column load
    (col_load == 1): physical row p of each array attenuates by
    ``ir_scale * (p+1)/rows``; deployment calibration divides out the
    mean-distance attenuation, leaving the placement-dependent residual.
    By default logical rows map to physical positions in natural banded
    order (feature-major, as the weights are flattened); ``perm`` — a
    KAN-SAM placement with ``perm[p] = logical row at physical position p``
    (see ``core.sam.sam_permutation``) — relocates each logical row's
    IR-drop exposure to its SAM slot instead.  Zero-padded rows past the
    logical row count keep gain 1 (they hold no conductance).
    """
    ir = cfg.ir_scale()
    if ir == 0.0:
        return None
    rows = cfg.array_rows
    nb = lp.spec.num_basis
    n_logical = lp.f * nb
    r = np.arange(lp.fp * nb)
    if perm is None:
        pos = r
    else:
        perm = np.asarray(perm)
        if perm.shape != (n_logical,):
            raise ValueError(
                f"sam perm has {perm.shape} entries; layer has {n_logical} "
                "logical rows"
            )
        inv = np.empty(n_logical, np.int64)
        inv[perm] = np.arange(n_logical)
        pos = np.where(r < n_logical, inv[np.minimum(r, n_logical - 1)], r)
    dist = ((pos % rows) + 1.0) / rows
    factor = 1.0 - ir * dist
    comp = 1.0 - ir * (rows + 1.0) / (2.0 * rows)
    gain = np.where(r < n_logical, factor / comp, 1.0)
    return gain.astype(np.float32)[:, None]


def _n_arrays(lp, cfg: CIMConfig) -> int:
    """Physical macro count one output column's MAC spans."""
    return max(1, -(-(lp.f * lp.spec.num_basis) // cfg.array_rows))


@dataclasses.dataclass
class ACIMExecutor(_CachedExecutor):
    """Fused pipeline with measured non-idealities at the MAC contraction.

    The injection points (all gated so a zeroed config traces the exact same
    program as "pallas"):

      * entry codes -> :func:`apply_input_noise` (TM-DV voltage/time sigma),
        re-rounded to the nearest valid ASP code;
      * conductance rows -> systematic IR-drop gain (mean-compensated, as on
        the calibrated 22nm prototype); an optional per-layer KAN-SAM
        placement (``sam_perms=``, see ``core.sam``) relocates each row's
        IR-drop exposure to its mapped physical slot, so the co-design
        search can score SAM on/off on the same fused backend;
      * each (batch, out) tile -> additive Gaussian partial-sum error with
        per-channel std ``sigma_ps * sqrt(n_arrays) * x_max * lut_lsb *
        w_lsb[o]`` — the float-domain image of ``cim_matmul``'s code-domain
        sigma, accumulated over the arrays a column spans.  Injected on the
        first contraction step, so the fused boundary requantizer propagates
        the error to the next layer's int codes.

    ``key`` seeds every stochastic term; the same key reproduces the run.
    When no key is supplied (e.g. the serving path, where ``ffn`` has no key
    plumbing), a default key is folded with a digest of the entry codes, so
    distinct layers/steps/tokens draw decorrelated noise while staying fully
    deterministic for identical inputs.
    """

    cim: CIMConfig = dataclasses.field(
        default_factory=lambda: CIMConfig(ir_gamma=0.06, sigma_ps_ref=0.05)
    )
    name: str = dataclasses.field(default="acim", init=False)

    def _flags(self, cim: CIMConfig | None = None, sam_perms=None,
               **_opts) -> tuple:
        flags = ("cim", self.cim if cim is None else cim)
        if sam_perms is not None:
            # per-layer KAN-SAM placements (or None to keep natural order);
            # tuples so the cache key stays hashable
            flags += ("sam", tuple(
                None if p is None else tuple(int(i) for i in np.asarray(p))
                for p in sam_perms
            ))
        return flags

    def _run(self, apply, codes, xraw, layers, key, return_intermediates):
        if key is None:
            key = jax.random.fold_in(
                jax.random.PRNGKey(0), jnp.sum(codes, dtype=jnp.uint32)
            )
        return apply(codes, xraw, layers, key,
                     return_intermediates=return_intermediates)

    def _statics(self, key: PlanKey) -> tuple:
        """(cfg, sam_perms, has_input_noise, has_psum) from the key."""
        cfg = key.flags[1]
        sam_perms = None
        if len(key.flags) >= 4 and key.flags[2] == "sam":
            sam_perms = key.flags[3]
        tm = cfg.input_gen
        has_input_noise = (not cfg.deterministic) and (
            tm.sigma_v > 0.0 or tm.sigma_t > 0.0
        )
        has_psum = (not cfg.deterministic) and cfg.sigma_ps_ref > 0.0
        return cfg, sam_perms, has_input_noise, has_psum

    @staticmethod
    def _layer_psum_std(cfg, lp, lw):
        """Per-channel partial-sum sigma of one layer, at ITS bit widths.

        ``x_max`` is the layer's LUT code ceiling (2**lut_bits - 1) and the
        per-channel weight LSB divides by the layer's signed weight-code
        ceiling (2**(w_bits-1) - 1, so a 4-bit layer's max |code| is 7 —
        its LSB, and hence its partial-sum error, is correspondingly
        coarser).  Packed layers decode through ``unpacked_wc`` first.
        """
        x_max = float(2 ** lp.spec.lut_bits - 1)
        w_qmax = float(2 ** (weight_bits(lp.spec) - 1) - 1)
        w_lsb = jnp.max(jnp.abs(unpacked_wc(lw, lp)), axis=0) / w_qmax
        lut_lsb = jnp.max(lw["lut"]) / x_max
        return (cfg.sigma_ps() * np.sqrt(_n_arrays(lp, cfg))
                * x_max * lut_lsb) * w_lsb

    def _row_gains(self, key: PlanKey, plan) -> tuple:
        cfg, sam_perms, *_ = self._statics(key)
        return tuple(
            _irdrop_row_gain(
                lp, cfg, perm=sam_perms[li] if sam_perms is not None else None
            )
            for li, lp in enumerate(plan.layers)
        )

    def _mesh_layer_fn(self, key: PlanKey, local_plan):
        """The pallas step with the systematic IR-drop gains folded into the
        shard-local conductance columns.  The gains are a full-length ROW
        vector (the contraction axis stays whole on every shard), so they
        broadcast unchanged against the shard's column slab."""
        base_fn = super()._mesh_layer_fn(key, local_plan)
        row_gains = self._row_gains(key, local_plan)

        def layer_fn(li, lp, lw, h_codes, h_raw, psum_noise):
            if row_gains[li] is not None:
                # gains break the uniform per-channel scale: decode packed
                # layers to the f32 operand before applying them (mirrors
                # the local path)
                wc = unpacked_wc(lw, lp) * jnp.asarray(row_gains[li])
                lw = {"lut": lw["lut"], "wc": wc, "wb": lw["wb"]}
            return base_fn(li, lp, lw, h_codes, h_raw, psum_noise)

        return layer_fn

    def _mesh_noise_fn(self, key: PlanKey, base_plan, local_plan, sharded):
        """Per-shard stochastic terms inside the shard_map body.

        The PRNG key splits per shard: the data index is folded in first
        (every batch slab draws decorrelated noise), and the model index is
        folded into a layer's partial-sum draw ONLY when that layer's
        columns are sharded — replicated layers must see identical noise on
        every model replica, and entry-code noise (codes are replicated
        across "model") likewise folds the data index only.  Per-tile sigma
        stays consistent under sharding by construction: each shard owns
        whole MAC columns, so the per-shard ``n_arrays`` (the physical
        macros one column's contraction spans) equals the unsharded value,
        and the per-channel ``w_lsb`` computed from the local column slab
        matches the same columns of the global weight matrix.
        """
        cfg, _, has_input_noise, has_psum = self._statics(key)
        if not (has_input_noise or has_psum):
            return None
        spec0 = key.specs[0]
        tm = cfg.input_gen

        def noise_fn(codes, layers, noise_key, ctx):
            k = jax.random.fold_in(noise_key, ctx.data_index)
            if has_input_noise:
                k, k_in = jax.random.split(k)
                eff = apply_input_noise(codes, tm, k_in)
                codes = jnp.clip(
                    jnp.floor(eff + 0.5).astype(jnp.int32),
                    0, spec0.num_codes - 1,
                )
            if not has_psum:
                return codes, None
            noises = []
            for li, (lp, lw) in enumerate(zip(local_plan.layers, layers)):
                std = self._layer_psum_std(cfg, lp, lw)
                k, k_ps = jax.random.split(k)
                if ctx.layer_sharded[li]:
                    k_ps = jax.random.fold_in(k_ps, ctx.model_index)
                noises.append(std[None, :] * jax.random.normal(
                    k_ps, (local_plan.bp, lp.op), jnp.float32))
            return codes, tuple(noises)

        return noise_fn

    def _build_local(self, key: PlanKey):
        cfg, sam_perms, has_input_noise, has_psum = self._statics(key)
        plan = PLAN_CACHE.plan(key.bucket, key.dims, key.specs,
                               residual_raw=key.residual_raw)
        spec0 = key.specs[0]
        tm = cfg.input_gen
        row_gains = self._row_gains(key, plan)

        @functools.partial(jax.jit, static_argnames=("return_intermediates",))
        def apply(codes, xraw, layers, noise_key, return_intermediates=False):
            PLAN_CACHE.record_trace()
            if has_input_noise:
                noise_key, k_in = jax.random.split(noise_key)
                eff = apply_input_noise(codes, tm, k_in)
                codes = jnp.clip(
                    jnp.floor(eff + 0.5).astype(jnp.int32),
                    0, spec0.num_codes - 1,
                )
            acim_layers = []
            noises = [] if has_psum else None
            for li, (lp, lw) in enumerate(zip(plan.layers, layers)):
                if has_psum:
                    # per-channel weight LSB recovered from the int-code
                    # storage at the layer's own bit widths; padded output
                    # channels have zero weights -> zero sigma, keeping the
                    # padded lanes noiseless.
                    std = self._layer_psum_std(cfg, lp, lw)
                    noise_key, k_ps = jax.random.split(noise_key)
                    noises.append(std[None, :] * jax.random.normal(
                        k_ps, (plan.bp, lp.op), jnp.float32))
                if row_gains[li] is not None:
                    # the per-row conductance gains break the uniform
                    # per-channel scale, so a packed layer falls back to
                    # the unpacked f32 operand for this noisy program
                    # (quiet configs never reach here: same keys, same
                    # packed kernel as "pallas")
                    wc = unpacked_wc(lw, lp) * jnp.asarray(row_gains[li])
                    acim_layers.append(
                        {"lut": lw["lut"], "wc": wc, "wb": lw["wb"]})
                else:
                    acim_layers.append(lw)
            return kan_pipeline_impl(
                codes, xraw, tuple(acim_layers), plan,
                interpret=key.interpret,
                psum_noises=tuple(noises) if noises is not None else None,
                return_intermediates=return_intermediates,
            )

        return plan, apply


register_executor("ref", RefExecutor())
register_executor("pallas", PallasExecutor())
register_executor("acim", ACIMExecutor())


def _obs_collect() -> dict:
    """Per-backend dispatch counts under the documented labeled series."""
    return {
        ("runtime.backend_dispatch", (("backend", name),)): count
        for name, count in sorted(DISPATCH_COUNTS.items())
    }


_OBS_REGISTRY.register_collector(_obs_collect)
