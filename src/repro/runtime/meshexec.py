"""Mesh-sharded execution of the KAN runtime (the distributed dimension).

The software analogue of the follow-up paper's multi-chip ACIM scaling
(partitioning spline arrays across tiles): the fused pipeline's **batch**
shards over the mesh's ``"data"`` axis and each layer's **output channels**
shard over ``"model"`` (the `dist.sharding.deployed_kan_pspecs` layout —
every shard owns whole MAC columns, so there is never a cross-shard
reduction inside a layer).  The inter-layer boundary requantizer stays
shard-local: each shard re-codes its own columns, then an all-gather over
``"model"`` restores the full-width code vector the next layer contracts
against (int32 codes — the cheapest possible boundary payload, exactly the
paper's inter-array traffic argument).

Resolution mirrors the backend registry: explicit ``mesh=`` argument >
:func:`use_mesh` scope > the bundle's recorded placement
(``DeployedKAN.placement``) > unsharded.  Geometry that cannot shard (a
model-axis size that does not divide a layer's padded output dim) falls
back to replicated columns for that layer, and the reason is recorded in
:func:`shard_notes`.

Everything here is glue around one ``shard_map``: the per-shard body drives
the SAME fused kernel (``kernels.kan_spline.run_pipeline_layer``) on a
per-shard plan (``shard_local_plan``), so a 1x1 mesh or a pure-``data`` mesh
is bit-identical to the unsharded path (row independence + whole-column
ownership) for every deterministic program — ``pallas``, ``ref``, and
quiet/deterministic ``acim`` — which the acceptance tests assert.  Noisy
``acim`` is the one exception: its PRNG stream is re-derived PER SHARD
(the data index is folded into the key), so binding any mesh changes the
draws; runs stay reproducible under a fixed key + fixed mesh.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # moved to the jax namespace in newer releases
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map


def _shard_map_norep(body, *, mesh, in_specs, out_specs):
    """shard_map without replication checking, across jax API renames.

    The rep checker cannot prove replication through pallas_call (no rep
    rule), so it must be off; the kwarg is ``check_rep`` on older jax and
    ``check_vma`` on releases where shard_map lives in the jax namespace.
    """
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return _shard_map(body, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)
        except TypeError:
            continue
    raise TypeError("no compatible shard_map signature found")

__all__ = [
    "ShardContext",
    "use_mesh",
    "resolve_mesh",
    "mesh_axis_sizes",
    "mesh_fingerprint",
    "register_mesh",
    "mesh_from_fingerprint",
    "shard_notes",
    "reset_shard_notes",
    "build_sharded_runner",
]

# innermost use_mesh() override; ContextVar for the same reason as the
# backend scope — concurrent engines must not clobber each other
_SCOPE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_kan_mesh_scope", default=None
)

# fingerprint core -> live Mesh (PlanKey must stay hashable/comparable, so
# the key carries the fingerprint and the Mesh object is parked here)
_MESHES: dict = {}
# fingerprint -> tuple of human-readable fallback reasons (replicated layers)
_NOTES: dict = {}


@contextlib.contextmanager
def use_mesh(mesh):
    """Scoped mesh override, mirroring :func:`use_backend`.

    ``None`` is a no-op passthrough so callers can plumb an optional choice.
    """
    token = _SCOPE_MESH.set(mesh if mesh is not None else _SCOPE_MESH.get())
    try:
        yield
    finally:
        _SCOPE_MESH.reset(token)


def resolve_mesh(mesh=None, placement=None):
    """Explicit arg > ``use_mesh`` scope > bundle placement > None."""
    if mesh is not None:
        return mesh
    scoped = _SCOPE_MESH.get()
    if scoped is not None:
        return scoped
    return placement


def mesh_axis_sizes(mesh) -> tuple:
    """(data_size, model_size) of a mesh; absent axes count as 1."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("data", 1)), int(sizes.get("model", 1))


def mesh_fingerprint(mesh, layer_sharded) -> tuple:
    """Hashable identity of (mesh layout x per-layer sharded-or-not).

    Axis names x sizes x flat device ids pin the physical layout (two
    meshes over the same devices in a different order are different
    programs); the per-layer bools keep a fallen-back-to-replicated
    geometry from colliding with a fully sharded one.
    """
    return (
        tuple(mesh.axis_names),
        tuple(int(s) for s in mesh.devices.shape),
        tuple(int(d.id) for d in mesh.devices.flat),
        tuple(bool(f) for f in layer_sharded),
    )


def register_mesh(fingerprint: tuple, mesh, notes=()) -> None:
    _MESHES[fingerprint[:3]] = mesh
    if notes:
        _NOTES[fingerprint] = tuple(notes)


def mesh_from_fingerprint(fingerprint: tuple):
    return _MESHES[fingerprint[:3]]


def shard_notes() -> dict:
    """Recorded sharding fallbacks: fingerprint -> reasons (for reporting)."""
    return dict(_NOTES)


def reset_shard_notes() -> None:
    _NOTES.clear()


@dataclasses.dataclass(frozen=True)
class ShardContext:
    """Per-shard coordinates handed to backend hooks inside the shard body.

    ``data_index``/``model_index`` are traced axis indices (or literal 0
    when the mesh lacks the axis); ``layer_sharded`` says which layers'
    columns are split on "model".  The acim backend folds these into its
    PRNG key so every shard draws decorrelated noise — but only folds the
    model index for layers whose columns are actually sharded, keeping
    replicated values bitwise replicated across the model axis.
    """

    data_index: object
    model_index: object
    layer_sharded: tuple


def build_sharded_runner(mesh, *, local_plan, layer_sharded, residual_raw,
                         layer_fn, noise_fn=None):
    """Build the shard_mapped pipeline runner for one cached executor entry.

    Returns ``runner(codes, xraw, layers, *extra)`` -> ``(y, boundaries)``:

      * ``codes``/``xraw`` are GLOBAL, already padded to the global batch
        bucket and the entry feature pad ``fp0``; the batch shards over
        "data" (each shard sees ``bucket / data_size`` rows, further padded
        to the local plan's ``bp`` when a tuned ``bb`` demands it);
      * ``layers`` shard their ``wc``/``wb`` columns over "model" wherever
        ``layer_sharded`` says so (``deployed_kan_pspecs`` layout), the
        SH-LUT is replicated;
      * ``extra`` is the backend's trailing operand (the acim PRNG key),
        replicated and re-derived per shard via ``noise_fn``;
      * ``y`` reassembles to the global (bucket, op_last) array, and
        ``boundaries`` are the full-width int32 boundary codes each layer
        handed to the next (already all-gathered over "model" — the gather
        is load-bearing: the next layer contracts the full feature axis).

    ``layer_fn(li, lp, lw, codes, xraw, psum_noise)`` runs ONE layer on the
    per-shard geometry; ``noise_fn(codes, layers, key, ctx)`` (optional)
    perturbs the entry codes and returns per-layer psum noise tiles.
    """
    axis_names = tuple(mesh.axis_names)
    dname = "data" if "data" in axis_names else None
    mname = "model" if "model" in axis_names else None
    n_layers = len(local_plan.layers)

    from ..kernels.kan_spline.pipeline import layer_weight_keys

    in_specs = [P(dname, None)]
    if residual_raw:
        in_specs.append(P(dname, None))
    # per-leaf specs follow each layer's ACTUAL deployed keys: SH-LUT leaves
    # (f32 or int4-packed) replicate; weight leaves — unpacked "wc", packed
    # "wcp" + its per-channel "wscale" row, and "wb" — shard their
    # output-channel (last) dim on "model" wherever the layer shards
    in_specs.append(tuple(
        {
            k: (P(None, None) if k.startswith("lut")
                else P(None, mname if sharded else None))
            for k in layer_weight_keys(lp)
        }
        for lp, sharded in zip(local_plan.layers, layer_sharded)
    ))
    if noise_fn is not None:
        in_specs.append(P(None))
    out_specs = (
        P(dname, mname if layer_sharded[-1] else None),
        tuple(P(dname, None) for _ in range(n_layers - 1)),
    )

    def body(*args):
        it = iter(args)
        codes = next(it)
        xraw = next(it) if residual_raw else None
        layers = next(it)
        nkey = next(it) if noise_fn is not None else None
        # a tuned bb may not divide the per-shard batch slab: pad rows up to
        # the local plan's bp inside the shard (rows are independent), slice
        # back before reassembly
        b_l = codes.shape[0]
        if b_l != local_plan.bp:
            codes = jnp.pad(codes, ((0, local_plan.bp - b_l), (0, 0)))
            if xraw is not None:
                xraw = jnp.pad(xraw, ((0, local_plan.bp - b_l), (0, 0)))
        ctx = ShardContext(
            data_index=jax.lax.axis_index(dname) if dname else 0,
            model_index=jax.lax.axis_index(mname) if mname else 0,
            layer_sharded=layer_sharded,
        )
        noises = None
        if noise_fn is not None:
            codes, noises = noise_fn(codes, layers, nkey, ctx)
        h_codes, h_raw = codes, xraw
        y = None
        boundary = []
        for li, (lp, lw) in enumerate(zip(local_plan.layers, layers)):
            y, nxt = layer_fn(
                li, lp, lw, h_codes, h_raw,
                noises[li] if noises is not None else None,
            )
            if nxt is None:
                continue  # last layer: f32 output only
            y_next = y if residual_raw else None
            if layer_sharded[li] and mname:
                # the shard-local requantizer has already re-coded this
                # shard's columns; gather the int codes (and the raw f32
                # copy the FFN ReLU branch needs) to full width
                nxt = jax.lax.all_gather(nxt, mname, axis=1, tiled=True)
                if y_next is not None:
                    y_next = jax.lax.all_gather(
                        y_next, mname, axis=1, tiled=True
                    )
            boundary.append(nxt)
            h_codes, h_raw = nxt, y_next
        return y[:b_l], tuple(c[:b_l] for c in boundary)

    fn = _shard_map_norep(
        body, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs,
    )

    def runner(codes, xraw, layers, *extra):
        args = [codes]
        if residual_raw:
            args.append(xraw)
        args.append(layers)
        if noise_fn is not None:
            # only the stochastic path consumes the trailing PRNG key; a
            # quiet/deterministic config ignores it (same as the local path,
            # where the zeroed terms are compiled out)
            args.extend(extra)
        return fn(*args)

    return runner
