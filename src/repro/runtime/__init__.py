"""Backend-pluggable KAN runtime: executor registry + plan/compile cache.

The single dispatch point for every quantized-KAN execution surface.  See
:mod:`repro.runtime.executor` (the ``ref`` / ``pallas`` / ``acim`` backends,
``REPRO_KAN_BACKEND`` resolution) and :mod:`repro.runtime.plancache` (batch
bucketing + the LRU of compiled applies).  :mod:`repro.runtime.attention`
carries the same registry pattern for the attention half of a block: the
"ref" / "flash" SDPA backends, resolved as explicit arg >
``use_attn_backend`` scope > ``REPRO_ATTN_BACKEND`` > hardware default.

    from repro import runtime
    y = runtime.execute(dep, x)                      # resolved backend
    y = runtime.execute(dep, x, backend="acim",      # paper non-idealities
                        key=jax.random.PRNGKey(0))
"""

from .attention import (
    ENV_ATTN_BACKEND_VAR,
    available_attn_backends,
    default_attn_backend,
    register_attn_backend,
    resolve_attn_backend,
    use_attn_backend,
)
from .executor import (
    ACIMExecutor,
    ENV_BACKEND_VAR,
    PallasExecutor,
    RefExecutor,
    available_backends,
    default_interpret,
    dispatch_counts,
    get_executor,
    quiet_cim_config,
    ref_composition,
    register_executor,
    reset_dispatch_counts,
    resolve_backend,
    use_backend,
)
from .meshexec import (
    mesh_axis_sizes,
    reset_shard_notes,
    resolve_mesh,
    shard_notes,
    use_mesh,
)
from .plancache import PLAN_CACHE, PlanCache, PlanKey, bucket_batch

__all__ = [
    "ACIMExecutor",
    "ENV_ATTN_BACKEND_VAR",
    "ENV_BACKEND_VAR",
    "PLAN_CACHE",
    "PallasExecutor",
    "PlanCache",
    "PlanKey",
    "RefExecutor",
    "available_attn_backends",
    "available_backends",
    "bucket_batch",
    "cache_stats",
    "default_attn_backend",
    "default_interpret",
    "dispatch_counts",
    "reset_dispatch_counts",
    "execute",
    "get_executor",
    "mesh_axis_sizes",
    "quiet_cim_config",
    "ref_composition",
    "register_attn_backend",
    "register_executor",
    "reset_cache",
    "resolve_attn_backend",
    "resolve_backend",
    "resolve_mesh",
    "shard_notes",
    "use_attn_backend",
    "use_backend",
    "use_mesh",
]


def execute(dep, x, *, backend=None, default="pallas", **opts):
    """Run a deployed KAN bundle through the resolved backend."""
    return get_executor(backend, default=default)(dep, x, **opts)


def cache_stats() -> dict:
    """Hit/miss/trace counters of the process-wide plan cache."""
    return PLAN_CACHE.stats()


def reset_cache() -> None:
    """Drop all cached plans/compiled applies and zero the counters."""
    PLAN_CACHE.clear()
    reset_shard_notes()
