"""Attention-backend registry: the dispatch point for SDPA execution.

Mirrors the KAN executor registry (:mod:`repro.runtime.executor`) for the
other FLOP-heavy op of a block: scaled-dot-product attention.  Unlike the
KAN registry, entries here are NAMES, not callables — the implementations
live in :mod:`repro.models.layers` (``_sdpa`` dispatches on the resolved
name), keeping this module dependency-free so the models package can import
it at module level.

Registered backends:

  * ``"ref"``   — the chunked XLA composition (``layers._sdpa_ref``):
                  position-built masks, query chunking under ``lax.scan``,
                  guarded masked softmax.  The parity oracle.
  * ``"flash"`` — the fused Pallas flash-attention kernel
                  (:mod:`repro.kernels.attention`): online softmax with a
                  running max/denominator over tiled KV streaming, GQA-aware
                  (one KV head tile serves its whole query group).  Runs in
                  interpret mode off-TPU.

Selection precedence matches the KAN registry: explicit argument >
:func:`use_attn_backend` scope > ``REPRO_ATTN_BACKEND`` env var > the
hardware default (:func:`default_attn_backend`: "flash" on TPU, "ref"
elsewhere — the automatic off-TPU fallback; "flash" can still be forced
off-TPU, where the kernel executes via ``default_interpret()``).

Resolution happens at TRACE time: anything that jits a step around
``_sdpa`` must either re-trace when the backend changes or carry the
resolved name in its jit key (``ServeEngine`` passes it as a static
argument to its compiled prefill/decode closures).
"""

from __future__ import annotations

import contextlib
import contextvars
import os

from .executor import default_interpret

__all__ = [
    "ENV_ATTN_BACKEND_VAR",
    "available_attn_backends",
    "default_attn_backend",
    "register_attn_backend",
    "resolve_attn_backend",
    "use_attn_backend",
]

ENV_ATTN_BACKEND_VAR = "REPRO_ATTN_BACKEND"

_ATTN_BACKENDS: list = []
# innermost use_attn_backend() override; a ContextVar so concurrent engines
# on different threads/async tasks cannot clobber each other's scope
_SCOPE_ATTN: contextvars.ContextVar = contextvars.ContextVar(
    "repro_attn_backend_scope", default=None
)


def register_attn_backend(name: str) -> None:
    if name not in _ATTN_BACKENDS:
        _ATTN_BACKENDS.append(name)


def available_attn_backends() -> tuple:
    return tuple(sorted(_ATTN_BACKENDS))


def default_attn_backend() -> str:
    """"flash" on TPU; the XLA ref path everywhere else (the Pallas kernel
    would only run in interpret mode there — correct but slow)."""
    return "ref" if default_interpret() else "flash"


def resolve_attn_backend(backend: str | None = None, *,
                         default: str | None = None) -> str:
    """Resolve an attention backend name; ValueError for unknown names."""
    if backend is None or backend == "auto":
        backend = _SCOPE_ATTN.get()
    if backend is None:
        backend = os.environ.get(ENV_ATTN_BACKEND_VAR, "").strip() or None
    if backend is None:
        backend = default_attn_backend() if default is None else default
    if backend not in _ATTN_BACKENDS:
        raise ValueError(
            f"unknown attention backend {backend!r}; "
            f"registered: {available_attn_backends()}"
        )
    return backend


@contextlib.contextmanager
def use_attn_backend(backend: str | None):
    """Scoped override (beats the env var, loses to explicit arguments).

    ``None`` is a no-op passthrough so callers can plumb an optional choice.
    """
    if backend is not None and backend not in _ATTN_BACKENDS:
        raise ValueError(
            f"unknown attention backend {backend!r}; "
            f"registered: {available_attn_backends()}"
        )
    token = _SCOPE_ATTN.set(
        backend if backend is not None else _SCOPE_ATTN.get()
    )
    try:
        yield
    finally:
        _SCOPE_ATTN.reset(token)


register_attn_backend("ref")
register_attn_backend("flash")
