"""Batch-bucketed plan + compiled-executor cache (the runtime's memo layer).

Every execution surface used to rebuild its :class:`PipelinePlan` and retrace
the fused jit for every distinct batch size (``DeployedKAN.replan`` per call,
``ServeEngine`` per prompt length).  This module centralizes that:

  * **bucketing** — a logical batch ``b`` is rounded up to the next power of
    two (:func:`bucket_batch`); inputs are zero-padded to the bucket and the
    output sliced back.  Rows are independent through the whole datapath
    (the MAC contracts the feature axis only), so padding is bit-invisible
    to the real rows.  A ragged request stream therefore compiles O(log B)
    executor variants instead of O(#distinct batch sizes).

  * **LRU cache** — ``(dims, specs, bucket, residual_raw, interpret,
    backend, flags) -> (PipelinePlan, compiled apply)``.  The compiled apply
    is a per-entry ``jax.jit`` closure over the static plan, so evicting an
    entry releases its executable.  Backend-specific statics (e.g. the acim
    :class:`~repro.core.cim.CIMConfig`, whose sigmas are baked into the
    traced program) ride in ``flags``.

  * **observability** — hit/miss/trace counters (`stats`), used by the
    recompile-count tests and the benchmark's cache report.  ``traces``
    increments inside the jitted python body, i.e. exactly once per real
    retrace, which is what the ragged-batch test asserts on.

  * **tuned tile plans** — ``repro.tune.tiles`` registers measured
    ``(bb, bo, bf)`` winners per ``(dims, specs, residual_raw)`` geometry
    (:meth:`PlanCache.set_tile_overrides`); :meth:`PlanCache.plan` applies
    them when building plans, so ``DeployedKAN.replan``, the executors and
    the serving path all pick the tuned geometry up transparently.
    Registering (or clearing) overrides invalidates the matching cached
    plans/compiled entries so no consumer keeps serving the stale geometry.
"""

from __future__ import annotations

import collections
import dataclasses
import threading

__all__ = ["bucket_batch", "PlanKey", "PlanCache", "PLAN_CACHE"]


def bucket_batch(b: int, lo: int = 8) -> int:
    """Round a logical batch up to the next power of two (>= ``lo``)."""
    if b < 1:
        raise ValueError(f"batch must be >= 1, got {b}")
    p = lo
    while p < b:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Hashable identity of one compiled executor variant."""

    dims: tuple
    specs: tuple            # per-layer ASPQuantSpec (frozen dataclasses)
    # padded batch: lo * 2^k (lo=8 unsharded -> powers of two; under a mesh
    # lo=8*data_size, so the bucket divides by ANY data-axis size but is not
    # necessarily a power of two).  GLOBAL (pre-shard) under a mesh.
    bucket: int
    residual_raw: bool
    interpret: bool
    backend: str
    flags: tuple = ()       # backend statics (e.g. ("cim", CIMConfig(...)))
    # mesh fingerprint: () for single-device execution, else (axis names,
    # axis sizes, flat device ids, per-layer model-sharded bools) — see
    # runtime.meshexec.mesh_fingerprint.  Sharded and unsharded entries can
    # therefore never collide, and two meshes only share an entry when they
    # lay the same devices out the same way.
    mesh: tuple = ()


class PlanCache:
    """LRU of PlanKey -> (PipelinePlan, compiled apply) with counters."""

    def __init__(self, maxsize: int = 64):
        self.maxsize = maxsize
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._plans: collections.OrderedDict = collections.OrderedDict()
        self._tile_overrides: dict = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.traces = 0

    # -- compiled-executor entries --------------------------------------

    def get(self, key: PlanKey, builder):
        """Return the cached (plan, apply) for ``key``; build on miss.

        ``builder(key)`` must return the ``(plan, apply)`` pair; ``apply``
        should bump :attr:`traces` from inside its traced python body so the
        counter reflects actual retraces, not cache misses.
        """
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                return entry
            self.misses += 1
            entry = builder(key)
            self._entries[key] = entry
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
            return entry

    def record_trace(self) -> None:
        """Called from inside a jitted apply body: one real (re)trace."""
        self.traces += 1

    # -- plan-only lookups (DeployedKAN.replan) -------------------------

    def plan(self, batch: int, dims: tuple, specs: tuple, *,
             residual_raw: bool = False):
        """Memoized ``make_pipeline_plan`` — replan becomes a dict lookup.

        Applies any tuned tile overrides registered for this geometry, so
        every consumer that resolves plans through the cache transparently
        runs on the tuned block sizes.
        """
        from ..kernels.kan_spline.pipeline import make_pipeline_plan

        key = (batch, tuple(dims), tuple(specs), residual_raw)
        with self._lock:
            plan = self._plans.get(key)
            if plan is None:
                overrides = self._tile_overrides.get(
                    (tuple(dims), tuple(specs), residual_raw)
                )
                plan = make_pipeline_plan(
                    batch, tuple(dims), tuple(specs),
                    residual_raw=residual_raw, tile_overrides=overrides,
                )
                self._plans[key] = plan
                while len(self._plans) > 4 * self.maxsize:
                    self._plans.popitem(last=False)
            else:
                self._plans.move_to_end(key)
            return plan

    # -- tuned tile-plan registry (repro.tune.tiles) --------------------

    def set_tile_overrides(self, dims: tuple, specs: tuple,
                           residual_raw: bool, overrides) -> None:
        """Register (or with ``overrides=None`` clear) a tuned tile plan.

        ``overrides`` is a per-layer ``((bb, bo, bf), ...)`` tuple (see
        ``kernels.kan_spline.pipeline.make_pipeline_plan``).  Cached plans
        and compiled entries for the geometry are invalidated so the next
        resolution rebuilds on the tuned blocks; the tile tuner re-warms the
        hot entry right after registration so consumers keep hitting the
        cache without a retrace of their own.
        """
        from ..kernels.kan_spline.pipeline import normalize_tile_overrides

        gkey = (tuple(dims), tuple(specs), bool(residual_raw))
        with self._lock:
            if overrides is None:
                if gkey not in self._tile_overrides:
                    return  # nothing registered: clearing must not invalidate
                del self._tile_overrides[gkey]
            else:
                self._tile_overrides[gkey] = normalize_tile_overrides(
                    overrides, len(dims) - 1
                )
            for k in [k for k in self._plans
                      if (k[1], k[2], k[3]) == gkey]:
                del self._plans[k]
            for k in [k for k in self._entries
                      if (k.dims, k.specs, k.residual_raw) == gkey]:
                del self._entries[k]

    def get_tile_overrides(self, dims: tuple, specs: tuple,
                           residual_raw: bool):
        """The registered tuned tile plan for a geometry, or None."""
        with self._lock:
            return self._tile_overrides.get(
                (tuple(dims), tuple(specs), bool(residual_raw))
            )

    def tile_overrides(self) -> dict:
        """Snapshot of every registered tuned tile plan (for reporting)."""
        with self._lock:
            return dict(self._tile_overrides)

    # -- stats ----------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "traces": self.traces,
                "entries": len(self._entries),
            }

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._plans.clear()
            self._tile_overrides.clear()
            self.hits = self.misses = self.traces = 0


# The process-wide cache every executor resolves through.
PLAN_CACHE = PlanCache()


def _obs_collect() -> dict:
    """Feed the cache counters to the obs registry under their documented
    dotted names (docs/observability.md) — pulled at snapshot time, so the
    cache's hot path pays nothing for observability."""
    return {f"plan_cache.{k}": v for k, v in PLAN_CACHE.stats().items()}


from ..obs import REGISTRY as _OBS_REGISTRY  # noqa: E402 - avoid cycle risk

_OBS_REGISTRY.register_collector(_obs_collect)
