"""Training entrypoint.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

On a real pod the same entrypoint runs under `jax.distributed.initialize()`
with the production mesh; on this container use --smoke (reduced config,
local devices).  All fault-tolerance machinery (checkpoint/restart, NaN
guards, straggler watchdog, SIGTERM-safe preemption) is active either way.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from ..configs.registry import get_config, smoke_config
from ..data.lm_data import DataConfig
from ..train.loop import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kan-ffn", action="store_true",
                    help="swap in the paper's KAN-FFN")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.kan_ffn:
        cfg = cfg.kan_variant()
    if args.smoke:
        cfg = dataclasses.replace(cfg, microbatch=0)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    loop = TrainLoop(cfg, dcfg, args.ckpt_dir, ckpt_every=args.ckpt_every)
    loop.install_sigterm_handler()
    print(f"arch={cfg.name} devices={jax.device_count()} "
          f"start_step={loop.start_step}")
    hist = loop.run(args.steps)
    if hist:
        print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}; "
              f"stragglers={loop.watchdog.straggler_steps}")


if __name__ == "__main__":
    main()
