"""Trip-count-aware HLO analysis: flops / memory traffic / collectives.

XLA's flat ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so
scan-over-layers and microbatch-accumulation loops (the whole point of the
production lowering) are undercounted by their trip counts.  This module
parses ``compiled.as_text()`` (the scheduled per-device SPMD module), builds
the computation call graph, and expands:

  * ``while``  -> body+condition x ``known_trip_count`` (backend_config)
  * ``call``   -> callee (fully)
  * ``fusion`` -> callee for FLOPs only (fusion internals are not HBM traffic)

Costs:
  * flops: 2 * prod(result_dims) * prod(lhs contracting dims) per ``dot``.
  * bytes: 2 x sum of result-buffer sizes of traffic-producing instructions
    (each buffer is written once and read ~once downstream) — a scheduled-
    module HBM-traffic proxy.
  * collective bytes: result sizes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (per-device payload).

Roofline terms (v5e targets):
    compute    = flops_per_device / 197e12        (bf16 MXU peak)
    memory     = bytes_per_device / 819e9         (HBM bandwidth)
    collective = collective_bytes_per_device / 50e9  (ICI per link)
"""

from __future__ import annotations

import re
from collections import defaultdict

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NO_TRAFFIC_OPS = {
    "tuple", "get-tuple-element", "parameter", "bitcast", "while",
    "constant", "after-all", "iota", "reshape", "conditional", "call",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def parse_computations(text: str):
    """-> (comps: name -> [instruction lines], entry_name)."""
    comps: dict = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if (line.startswith("%") or line.startswith("ENTRY")) and \
                line.rstrip().endswith("{"):
            header = line[len("ENTRY "):] if line.startswith("ENTRY") else line
            name = header.split(" ", 1)[0].lstrip("%")
            comps[name] = []
            cur = name
            if line.startswith("ENTRY"):
                entry = name
        elif line.startswith("}"):
            cur = None
        elif cur is not None and "=" in line:
            comps[cur].append(line.strip())
    return comps, entry


def _parse_instr(ln: str):
    """-> (name, result_type, op, operands_and_attrs) or None.

    Handles tuple result types containing nested parens and /*index=N*/
    comments, which defeat single-regex parsing.
    """
    s = ln
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        rtype = rest[: end + 1]
        after = rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        after = rest[sp + 1:]
    m = re.match(r"([\w\-]+)\(", after)
    if not m:
        return None
    return name, rtype, m.group(1), after
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_FIRST_OPERAND_RE = re.compile(r"\(\s*%([\w\.\-]+)")


class _CompCost:
    __slots__ = ("flops", "bytes", "coll", "coll_by_kind", "unknown_trips")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = 0.0
        self.coll_by_kind = defaultdict(float)
        self.unknown_trips = 0

    def add(self, other, mult=1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll += other.coll * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * mult
        self.unknown_trips += other.unknown_trips


def _analyze_module(text: str) -> dict:
    comps, entry = parse_computations(text)

    # per-computation symbol tables: instr name -> result type string
    symtab = {}
    for cname, lines in comps.items():
        tab = {}
        for ln in lines:
            pi = _parse_instr(ln)
            if pi:
                tab[pi[0]] = pi[1]
        symtab[cname] = tab

    memo: dict = {}

    def cost_of(cname: str, stack=()) -> _CompCost:
        if cname in memo:
            return memo[cname]
        if cname in stack or cname not in comps:
            return _CompCost()
        out = _CompCost()
        tab = symtab[cname]
        for ln in comps[cname]:
            pi = _parse_instr(ln)
            if pi is None:
                continue
            _, rtype, op, after = pi

            if op == "while":
                tm = _TRIP_RE.search(ln)
                trips = int(tm.group(1)) if tm else 1
                if not tm:
                    out.unknown_trips += 1
                bm = re.search(r"body=%([\w\.\-]+)", ln)
                cm = _COND_RE.search(ln)
                if bm:
                    out.add(cost_of(bm.group(1), stack + (cname,)), trips)
                if cm:
                    out.add(cost_of(cm.group(1), stack + (cname,)), trips)
                continue

            if op == "call":
                cm = re.search(r"to_apply=%([\w\.\-]+)", ln)
                if cm:
                    out.add(cost_of(cm.group(1), stack + (cname,)))
                continue

            if op == "fusion":
                cm = re.search(r"calls=%([\w\.\-]+)", ln)
                if cm:
                    sub = cost_of(cm.group(1), stack + (cname,))
                    out.flops += sub.flops  # dots inside fusions still count
                out.bytes += 2 * _shape_bytes(rtype)
                continue

            if op == "dot":
                dims = _first_shape_dims(rtype) or []
                flops = 2.0
                for d in dims:
                    flops *= d
                cm = _LHS_CONTRACT_RE.search(after)
                opm = _FIRST_OPERAND_RE.search(after)
                if cm and opm:
                    lhs_type = tab.get(opm.group(1), "")
                    lhs_dims = _first_shape_dims(lhs_type) or []
                    for idx in (int(i) for i in cm.group(1).split(",") if i):
                        if idx < len(lhs_dims):
                            flops *= lhs_dims[idx]
                out.flops += flops
                out.bytes += 2 * _shape_bytes(rtype)
                continue

            is_coll = False
            for c in _COLLECTIVES:
                if op == c or op == c + "-start":
                    nbytes = _shape_bytes(rtype)
                    out.coll += nbytes
                    out.coll_by_kind[c] += nbytes
                    out.bytes += 2 * nbytes
                    is_coll = True
                    break
            if is_coll:
                continue

            if op in _NO_TRAFFIC_OPS or op.endswith("-done"):
                continue
            out.bytes += 2 * _shape_bytes(rtype)
        memo[cname] = out
        return out

    total = cost_of(entry) if entry else _CompCost()
    return {
        "flops": total.flops,
        "bytes": total.bytes,
        "collective_bytes": total.coll,
        "collective_by_kind": dict(total.coll_by_kind),
        "unknown_trip_loops": total.unknown_trips,
    }


def roofline(flops_per_dev: float, bytes_per_dev: float,
             coll_bytes_per_dev: float) -> dict:
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom
    terms["step_time_lb_s"] = bound
    terms["roofline_fraction"] = compute_s / bound if bound > 0 else 0.0
    return terms


def analyze_compiled(compiled, mesh_devices: int) -> dict:
    """Full report from a jax compiled artifact (per-device numbers)."""
    txt = compiled.as_text()
    parsed = _analyze_module(txt)

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        mem["peak_bytes"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
            - mem["alias_bytes"]
        )
    coll = dict(parsed["collective_by_kind"])
    coll["total"] = parsed["collective_bytes"]
    return {
        "devices": mesh_devices,
        "flops_per_dev": parsed["flops"],
        "bytes_per_dev": parsed["bytes"],
        "collectives": coll,
        "unknown_trip_loops": parsed["unknown_trip_loops"],
        "xla_flat_flops": float(ca.get("flops", 0.0)),
        "xla_flat_bytes": float(ca.get("bytes accessed", 0.0)),
        "memory": mem,
        "roofline": roofline(
            parsed["flops"], parsed["bytes"], parsed["collective_bytes"]
        ),
    }
