"""Production mesh builders.

make_production_mesh is a FUNCTION (not module-level state) so importing this
module never touches jax device initialization — only dryrun.py (which sets
XLA_FLAGS first) materializes the 512-way host-device mesh.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _make_mesh(shape, axes):
    """Version-compatible mesh construction: axis_types / AxisType only
    exist on newer jax; fall back through the older APIs."""
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):
        pass
    try:
        return jax.make_mesh(shape, axes)
    except AttributeError:
        import numpy as np

        n = int(np.prod(shape))
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return jax.sharding.Mesh(devs, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: (16,16)=256 chips single-pod; (2,16,16)=512 two-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    return _make_mesh((data, model), ("data", "model"))
