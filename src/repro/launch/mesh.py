"""Production mesh builders.

make_production_mesh is a FUNCTION (not module-level state) so importing this
module never touches jax device initialization — only dryrun.py (which sets
XLA_FLAGS first) materializes the 512-way host-device mesh.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "parse_mesh_spec"]


def _make_mesh(shape, axes):
    """Version-compatible mesh construction: axis_types / AxisType only
    exist on newer jax; fall back through the older APIs."""
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):
        pass
    try:
        return jax.make_mesh(shape, axes)
    except AttributeError:
        import numpy as np

        n = int(np.prod(shape))
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return jax.sharding.Mesh(devs, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: (16,16)=256 chips single-pod; (2,16,16)=512 two-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    return _make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: str):
    """Build a (data, model) mesh from a CLI string like ``data=2,model=4``.

    Each comma-separated entry is ``axis`` or ``axis=N`` with axis in
    {data, model}.  The FIRST entry without ``=N`` absorbs every device the
    other axes leave over; further bare entries get size 1 — so on 8
    devices ``data,model=2`` is 4x2, ``data,model`` is 8x1.  Unnamed axes
    get size 1.  Raises ValueError for unknown axes, duplicate entries,
    non-positive sizes, or a layout that does not fit the device count.
    """
    sizes: dict = {}
    wildcard = None
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        name, _, num = entry.partition("=")
        name = name.strip()
        if name not in ("data", "model"):
            raise ValueError(f"unknown mesh axis {name!r} (want data/model)")
        if name in sizes or name == wildcard:
            raise ValueError(f"duplicate mesh axis {name!r}")
        if num:
            sizes[name] = int(num)
            if sizes[name] < 1:
                raise ValueError(f"mesh axis {name!r} must be >= 1: {num}")
        elif wildcard is None:
            wildcard = name
        else:
            sizes[name] = 1
    n_dev = len(jax.devices())
    explicit = 1
    for s in sizes.values():
        explicit *= s
    if wildcard is not None:
        if n_dev % explicit:
            raise ValueError(
                f"{explicit} explicit-axis devices do not divide {n_dev}"
            )
        sizes[wildcard] = n_dev // explicit
    total = sizes.get("data", 1) * sizes.get("model", 1)
    if total > n_dev:
        raise ValueError(f"mesh needs {total} devices, only {n_dev} present")
    return make_local_mesh(sizes.get("data", 1), sizes.get("model", 1))
