"""Serving entrypoint: continuous-batching engine over a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --requests 8 --slots 4
"""

from __future__ import annotations

import argparse

import jax

from ..configs.registry import smoke_config
from ..models.model import init_params
from ..serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kan-ffn", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if args.kan_ffn:
        cfg = cfg.kan_variant()
    if cfg.family in ("audio",):
        raise SystemExit("serve demo supports decoder-only archs")
    params = init_params(jax.random.PRNGKey(0), cfg)
    # --kan-ffn serves the paper's datapath: FFN blocks are ASP-quantized at
    # startup and every prefill/decode step runs them through the fused
    # kan_spline Pallas pipeline (interpret mode auto-selected off-TPU).
    engine = ServeEngine(params, cfg, slots=args.slots, max_len=128,
                         kan_deploy=args.kan_ffn)

    rng = jax.random.PRNGKey(1)
    reqs = []
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (8,), 3, cfg.vocab_size).tolist()
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new))
    results = engine.run(reqs, log=print)
    total = sum(len(r.output) for r in results)
    print(f"served {len(results)} requests / {total} tokens")


if __name__ == "__main__":
    main()
