"""Serving entrypoint: continuous-batching engine over a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --requests 8 --slots 4
    # the paper's datapath, with hardware non-idealities:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --kan-ffn --backend acim
"""

from __future__ import annotations

import argparse
import time

import jax

from ..configs.registry import smoke_config
from ..models.model import init_params
from ..serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kan-ffn", action="store_true")
    ap.add_argument(
        "--backend", default=None, choices=("ref", "pallas", "acim"),
        help="KAN executor backend (with --kan-ffn); default resolves via "
             "REPRO_KAN_BACKEND, then 'pallas'",
    )
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if args.kan_ffn:
        cfg = cfg.kan_variant()
    if cfg.family in ("audio",):
        raise SystemExit("serve demo supports decoder-only archs")
    params = init_params(jax.random.PRNGKey(0), cfg)
    # --kan-ffn serves the paper's datapath: FFN blocks are ASP-quantized at
    # startup and every prefill/decode step resolves its executor through
    # repro.runtime (interpret mode auto-selected off-TPU); --backend acim
    # additionally injects the measured RRAM-ACIM non-idealities.
    engine = ServeEngine(params, cfg, slots=args.slots, max_len=128,
                         kan_deploy=args.kan_ffn, kan_backend=args.backend)

    rng = jax.random.PRNGKey(1)
    reqs = []
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(4 + jax.random.randint(k, (), 0, 9))  # mixed-length stream
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (plen,), 3, cfg.vocab_size).tolist()
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    results = engine.run(reqs, log=print)
    wall = time.perf_counter() - t0
    total = sum(len(r.output) for r in results)
    stats = engine.compile_stats()
    print(f"served {len(results)} requests / {total} tokens "
          f"({total / wall:.1f} tok/s)")
    print(f"compiles: prefill={stats['prefill_traces']} "
          f"decode={stats['decode_traces']}; "
          f"kan plan cache: {stats['plan_cache']}")


if __name__ == "__main__":
    main()
