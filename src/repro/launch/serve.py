"""Serving entrypoint: async streaming scheduler over a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --requests 8 --slots 4
    # stream tokens as they are produced, sample instead of greedy decode:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --stream --sampling 0.8 --top-k 16 --seed 7
    # bounded queue + per-request deadlines (admission/backpressure demo):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --requests 16 --queue-limit 4 --deadline 2.0
    # the paper's datapath, with hardware non-idealities:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --kan-ffn --backend acim
    # deploy a repro.tune co-design artifact (quantization point + tuned
    # tile plan applied at startup):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --kan-ffn --tuned-config TUNE_artifact.json
    # mesh-sharded serving (slots/KV on "data", KAN-FFN channels on "model"):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch qwen2.5-14b --kan-ffn \
        --mesh data=4,model=2
    # paged KV pool with prefix caching and chunked prefill (vLLM-style;
    # greedy streams stay bit-identical to the contiguous slab):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --kv-block-size 16 --prefix-cache on --prefill-chunk 32
    # speculative decoding: a cheap refit KAN drafter proposes 4 tokens per
    # round, one batched target pass verifies them (streams bit-identical):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --kan-ffn --kv-block-size 16 --spec-decode 4 \
        --draft-spec grid=4,bits=6
    # observability: metrics registry + request tracing (docs/observability.md)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --metrics-dump metrics.prom --metrics-dump metrics.json \
        --trace-out trace.jsonl --stats-interval 1.0
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax

from .. import obs
from ..configs.registry import smoke_config
from ..models.model import init_params
from ..serve.engine import Request, ServeEngine
from ..serve.scheduler import QueueFull, SamplingParams, Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kan-ffn", action="store_true")
    ap.add_argument(
        "--stream", action="store_true",
        help="print every token as the scheduler produces it (on_token "
             "streaming) instead of only per-request completion lines",
    )
    ap.add_argument(
        "--sampling", type=float, default=0.0, metavar="TEMP",
        help="decode temperature; 0 (default) = greedy argmax, >0 samples "
             "with --top-k/--top-p under --seed (reproducible)",
    )
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the K highest logits (0=off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0=off)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling PRNG seed (per-request streams fold rid)")
    ap.add_argument(
        "--queue-limit", type=int, default=None, metavar="N",
        help="bounded scheduler queue: submissions past N waiting requests "
             "are rejected (admission backpressure); default unbounded",
    )
    ap.add_argument(
        "--deadline", type=float, default=None, metavar="S",
        help="per-request queueing deadline in seconds: a request still "
             "waiting after S is expired unserved",
    )
    ap.add_argument(
        "--backend", default=None, choices=("ref", "pallas", "acim"),
        help="KAN executor backend (with --kan-ffn); default resolves via "
             "REPRO_KAN_BACKEND, then 'pallas'",
    )
    ap.add_argument(
        "--attn-backend", default=None, choices=("ref", "flash"),
        help="attention backend: 'flash' = fused Pallas flash-attention "
             "kernel (online softmax, tiled KV; interpret mode off-TPU), "
             "'ref' = chunked XLA composition; default resolves via "
             "REPRO_ATTN_BACKEND, then flash on TPU / ref elsewhere",
    )
    ap.add_argument(
        "--kan-bits", default=None, metavar="BITS",
        help="with --kan-ffn: per-layer ASP bit widths for the two KANLinear "
             "halves, e.g. '8,4' (mixed precision; <=4-bit layers deploy "
             "int4-packed), or one value for uniform width.  A --tuned-"
             "config artifact's chosen allocation takes precedence; invalid "
             "PowerGap combinations are rejected at startup",
    )
    ap.add_argument(
        "--tuned-config", default=None, metavar="PATH",
        help="repro.tune artifact to deploy: applies its chosen "
             "quantization point to the KAN-FFN config and registers its "
             "tuned tile plan with the runtime plan cache",
    )
    ap.add_argument(
        "--mesh", default=None, metavar="SPEC",
        help="serve mesh-sharded: 'data=2,model=4' (one axis may omit =N to "
             "absorb the remaining devices, e.g. 'data,model=2').  Slots / "
             "KV cache shard on data, KAN-FFN output channels on model; "
             "takes precedence over any ambient runtime.use_mesh scope",
    )
    ap.add_argument(
        "--kv-block-size", type=int, default=None, metavar="TOKENS",
        help="paged KV cache: cut KV storage into blocks of this many "
             "tokens (a multiple of 8 — the flash kernel's KV tile; must "
             "divide max_len) with a free-list allocator, per-request block "
             "tables and a hash-keyed prefix cache; default keeps the "
             "contiguous per-slot slab.  Greedy streams are bit-identical "
             "either way",
    )
    ap.add_argument(
        "--prefix-cache", default="on", choices=("on", "off"),
        help="with --kv-block-size: share full prompt-prefix blocks across "
             "requests (shared system prompts prefill once); 'off' keeps "
             "the block pool a plain allocator",
    )
    ap.add_argument(
        "--spec-decode", type=int, default=0, metavar="K",
        help="speculative decoding: a cheap refit KAN drafter proposes K "
             "tokens per round and the target verifies all K+1 positions "
             "in one batched forward; greedy streams stay bit-identical "
             "(requires --kan-ffn and --kv-block-size); 0 = off",
    )
    ap.add_argument(
        "--draft-spec", default=None, metavar="SPEC",
        help="with --spec-decode: the drafter's deployment point, e.g. "
             "'grid=4,order=2,bits=6,backend=ref' (any subset of keys; "
             "defaults: half the target grid, same order/bits, engine "
             "backend)",
    )
    ap.add_argument(
        "--prefill-chunk", type=int, default=None, metavar="TOKENS",
        help="with --kv-block-size: prefill long prompts this many tokens "
             "per scheduling round, interleaved with pooled decode, so one "
             "long prompt can't stall TTFT for the pool; default prefills "
             "whole prompts at admission",
    )
    ap.add_argument(
        "--metrics-port", type=int, default=None, metavar="PORT",
        help="enable the obs metrics registry and serve Prometheus text at "
             "http://127.0.0.1:PORT/metrics (JSON at /metrics.json); 0 "
             "picks an ephemeral port",
    )
    ap.add_argument(
        "--metrics-dump", action="append", default=None, metavar="PATH",
        help="enable the obs metrics registry and write a snapshot at "
             "shutdown: '.json' suffix -> JSON snapshot, anything else -> "
             "Prometheus text exposition; repeatable",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="record one span tree per request and export it at shutdown: "
             "'.json' suffix -> Chrome trace-event JSON (chrome://tracing), "
             "anything else (e.g. '.jsonl') -> JSONL span records",
    )
    ap.add_argument(
        "--log-level", default=None,
        choices=("debug", "info", "warning", "error"),
        help="structured-logger threshold (sets REPRO_LOG_LEVEL for this "
             "process); debug shows per-request scheduler chatter",
    )
    ap.add_argument(
        "--stats-interval", type=float, default=None, metavar="S",
        help="emit a one-line scheduler stats summary every S seconds "
             "through the structured logger",
    )
    args = ap.parse_args()

    if args.log_level:
        os.environ[obs.ENV_LOG_LEVEL_VAR] = args.log_level
    if args.metrics_port is not None or args.metrics_dump:
        obs.enable()
    log = obs.get_logger("serve")

    cfg = smoke_config(args.arch)
    # bit-allocation precedence: artifact > --kan-bits CLI > config default
    if args.kan_bits:
        bits = tuple(int(b) for b in args.kan_bits.split(","))
        if len(bits) == 1:
            cfg = dataclasses.replace(cfg, kan_n_bits=bits[0],
                                      kan_layer_bits=())
        else:
            cfg = dataclasses.replace(cfg, kan_layer_bits=bits)
    tuned_note = ""
    if args.tuned_config:
        from ..tune import apply_tuning_artifact, load_tuning_artifact

        art = load_tuning_artifact(args.tuned_config)
        resolved = apply_tuning_artifact(art)
        cand = resolved["candidate"]
        if cand is not None:
            # the chosen co-design point becomes the KAN-FFN quantization
            # (including its per-layer mixed-precision allocation, which
            # overrides any --kan-bits request)
            cfg = dataclasses.replace(
                cfg, kan_grid=cand.grid_size, kan_order=cand.order,
                kan_n_bits=cand.n_bits, kan_layer_bits=cand.layer_bits,
            )
        tuned_note = (
            f" [artifact {args.tuned_config}: task={art.get('task')}, "
            f"seed={art.get('seed')}, space={art.get('space_hash')}, "
            f"tile mode={None if not art.get('tile_plan') else art['tile_plan'].get('mode')}]"
        )
    if args.kan_ffn:
        cfg = cfg.kan_variant()
        # fail fast on a PowerGap-invalid bit allocation (reject, not clamp)
        from ..core.asp_quant import resolve_layer_bits

        try:
            resolve_layer_bits(cfg.kan_layer_bits or cfg.kan_n_bits, 2,
                               cfg.kan_grid)
        except ValueError as e:
            raise SystemExit(f"invalid KAN bit allocation: {e}")
    if cfg.family in ("audio",):
        raise SystemExit("serve demo supports decoder-only archs")
    params = init_params(jax.random.PRNGKey(0), cfg)
    # --kan-ffn serves the paper's datapath: FFN blocks are ASP-quantized at
    # startup and every prefill/decode step resolves its executor through
    # repro.runtime (interpret mode auto-selected off-TPU); --backend acim
    # additionally injects the measured RRAM-ACIM non-idealities.
    mesh = None
    if args.mesh:
        from .mesh import parse_mesh_spec

        mesh = parse_mesh_spec(args.mesh)
    if args.prefill_chunk is not None and args.kv_block_size is None:
        raise SystemExit("--prefill-chunk requires --kv-block-size")
    if args.spec_decode:
        if not args.kan_ffn:
            raise SystemExit("--spec-decode requires --kan-ffn (the drafter "
                             "is refit from the deployed KAN-FFN weights)")
        if args.kv_block_size is None:
            raise SystemExit("--spec-decode requires --kv-block-size "
                             "(draft rollback releases pool blocks)")
    engine = ServeEngine(params, cfg, slots=args.slots, max_len=128,
                         kan_deploy=args.kan_ffn, kan_backend=args.backend,
                         attn_backend=args.attn_backend, mesh=mesh,
                         kv_block_size=args.kv_block_size,
                         prefix_cache=args.prefix_cache == "on",
                         prefill_chunk=args.prefill_chunk,
                         spec_decode=args.spec_decode,
                         draft_spec=args.draft_spec)
    if engine.draft is not None:
        d = engine.draft.describe()
        log.info("spec decode", k=engine.spec_k, draft_grid=d["kan_grid"],
                 draft_order=d["kan_order"], draft_bits=d["kan_n_bits"],
                 draft_backend=d["kan_backend"] or "inherit")
    if engine.paged:
        kv = engine.kv_stats()
        log.info("paged kv", blocks=kv["num_blocks"],
                 block_size=kv["block_size"],
                 prefix_cache="on" if kv["prefix_cache"] else "off",
                 prefill_chunk=kv["prefill_chunk"] or "whole-prompt")
    log.info("attention backend", backend=engine.attn_backend,
             fused_decode=engine.attn_backend == "flash" and args.kan_ffn)
    if mesh is not None:
        layout = engine.mesh_layout()
        log.info("mesh",
                 shape=" x ".join(f"{a}={s}" for a, s in
                                  zip(layout["axes"], layout["shape"])),
                 devices=f"{layout['devices']}/{len(jax.devices())}",
                 slots=("sharded" if layout["slots_sharded"]
                        else "replicated"))
    if args.kan_ffn:
        log.info("kan-ffn", G=cfg.kan_grid, K=cfg.kan_order,
                 n_bits=cfg.kan_n_bits,
                 layer_bits=("uniform" if not cfg.kan_layer_bits
                             else ",".join(map(str, cfg.kan_layer_bits))),
                 plan_source=engine.kan_plan_source() + tuned_note)

    metrics_server = None
    if args.metrics_port is not None:
        metrics_server = obs.start_metrics_server(args.metrics_port)
        log.info("metrics server",
                 url=f"http://127.0.0.1:{metrics_server.server_port}/metrics")

    sampling = None
    if args.sampling > 0.0:
        sampling = SamplingParams(temperature=args.sampling, top_k=args.top_k,
                                  top_p=args.top_p, seed=args.seed)
        log.info("sampling", temperature=sampling.temperature,
                 top_k=sampling.top_k, top_p=sampling.top_p,
                 seed=sampling.seed)

    rng = jax.random.PRNGKey(1)
    reqs = []
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(4 + jax.random.randint(k, (), 0, 9))  # mixed-length stream
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (plen,), 3, cfg.vocab_size).tolist()
        reqs.append(Request(rid=rid, prompt=prompt,
                            max_new_tokens=args.max_new,
                            deadline_s=args.deadline, sampling=sampling))

    sched = Scheduler(engine, max_queue=args.queue_limit,
                      log=None if args.stream else print,
                      trace=args.trace_out is not None,
                      stats_interval_s=args.stats_interval)
    on_token = None
    if args.stream:
        on_token = lambda r, tok: print(f"  req {r.rid} += {tok}", flush=True)
    dropped = 0
    for r in reqs:
        try:
            sched.submit(r, on_token=on_token)
        except QueueFull as e:
            dropped += 1
            log.warning("backpressure", detail=str(e))
    t0 = time.perf_counter()
    results = sched.run_until_idle()
    wall = time.perf_counter() - t0
    served = [r for r in results if r.status == "done"]
    total = sum(len(r.output) for r in served)
    stats = engine.compile_stats()
    log.info("served", requests=len(served), tokens=total,
             tokens_per_s=round(total / wall, 1), rejected=dropped)
    log.info("compiles", prefill=stats["prefill_traces"],
             decode=stats["decode_traces"], verify=stats["verify_traces"],
             kan_plan_cache=stats["plan_cache"])
    # shutdown metrics summary (the docs/serving.md glossary)
    s = sched.stats()

    def _ms(v):
        return "n/a" if v is None else f"{v * 1e3:.1f}ms"

    log.info("scheduler", submitted=s["submitted"], completed=s["completed"],
             expired=s["expired"], rejected=s["rejected"])
    ttft = s["ttft_s"] or {"p50": None, "p95": None}
    log.info("latency", ttft_p50=_ms(ttft["p50"]), ttft_p95=_ms(ttft["p95"]),
             itl_p50=_ms(s["itl_s"]["p50"]), itl_p95=_ms(s["itl_s"]["p95"]),
             tokens_per_s=round(s["tokens_per_s"] or 0.0, 1))
    log.info("queue depth", max=s["queue_depth"]["max"],
             mean=round(s["queue_depth"]["mean"], 2),
             samples=s["queue_depth"]["samples"])
    if s["kv"] is not None:
        kv = s["kv"]
        log.info("kv pool", hit_rate=round(kv["prefix_hit_rate"], 2),
                 hits=kv["prefix_hits"], misses=kv["prefix_misses"],
                 in_use=kv["blocks_in_use"], cached=kv["blocks_cached"],
                 free=kv["blocks_free"], evictions=kv["evictions"],
                 truncations=kv["truncations"])
    if s["spec"] is not None:
        sp = s["spec"]
        log.info("spec decode", k=sp["k"], rounds=sp["rounds"],
                 drafted=sp["drafted"], accepted=sp["accepted"],
                 accept_rate=(round(sp["accept_rate"], 3)
                              if sp["accept_rate"] is not None else None),
                 draft_p50=_ms(sp["draft_s"]["p50"]),
                 verify_p50=_ms(sp["verify_s"]["p50"]),
                 tokens_per_round=(round(s["tokens_per_round"], 2)
                                   if s["tokens_per_round"] is not None
                                   else None))
    if mesh is not None:
        from .. import runtime

        for fp, reasons in runtime.shard_notes().items():
            for r in reasons:
                log.warning("shard fallback", reason=r)

    if args.trace_out:
        if args.trace_out.endswith(".json"):
            sched.tracer.export_chrome(args.trace_out)
        else:
            sched.tracer.export_jsonl(args.trace_out)
        log.info("trace written", path=args.trace_out,
                 records=len(sched.tracer.records()))
    for path in args.metrics_dump or ():
        obs.dump_metrics(path)
        log.info("metrics dump written", path=path)
    if metrics_server is not None:
        metrics_server.shutdown()


if __name__ == "__main__":
    main()
