import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. eval_shape's the full train/serve state (no allocation anywhere),
  3. jit-lowers the step with the sharding rules of dist/sharding.py,
  4. compiles, and records memory_analysis() (proves per-device fit) +
     cost_analysis() + the parsed collective schedule (feeds §Roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out reports/dryrun
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..configs.registry import ARCHS, LONG_OK, SHAPES, cells, get_config
from ..dist import sharding as shd
from ..models import model as M
from ..train.train_state import init_state, make_train_step
from .hlo_analysis import analyze_compiled
from .mesh import make_production_mesh


# ----------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ----------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Abstract (ShapeDtypeStruct) inputs for one cell — weak-type-correct,
    shardable, never allocated."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if sh["kind"] == "train":
        spec = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
    elif sh["kind"] == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode: one new token against a seq_len KV cache
        spec = {
            "token": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }
    if cfg.family == "audio" and sh["kind"] != "decode":
        spec["enc_embeds"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), f)
    if cfg.family == "vlm" and sh["kind"] != "decode":
        spec["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.patch_embed_dim), f
        )
    return spec


def _batch_shardings(spec, mesh, batch_size):
    bp = shd.batch_pspec(mesh, batch_size)

    def one(s):
        nd = len(s.shape)
        parts = list(bp) + [None] * (nd - len(bp))
        return NamedSharding(mesh, P(*parts[:nd]))

    return jax.tree.map(one, spec)


# ----------------------------------------------------------------------------
# per-cell lowering
# ----------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, mesh, save_hlo: str | None = None,
               overrides: dict | None = None):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    key = jax.random.PRNGKey(0)
    t0 = time.time()

    # activation-sharding constraint for the residual stream (per-microbatch
    # batch for train; request batch for serve)
    from ..models import layers as L

    act_b = b // max(1, cfg.microbatch) if sh["kind"] == "train" else b
    act_bp = shd.batch_pspec(mesh, act_b)
    seq_ax = "model" if (cfg.seq_shard_acts and sh["kind"] == "train") else None
    L.set_activation_spec(
        jax.sharding.NamedSharding(mesh, P(*act_bp, seq_ax, None))
    )

    with mesh:
        if sh["kind"] == "train":
            state_shape = jax.eval_shape(lambda: init_state(key, cfg))
            pspecs = {
                "params": shd.param_pspecs(state_shape["params"], mesh),
                "opt": shd.opt_state_pspecs(
                    state_shape["opt"], state_shape["params"], mesh, zero1=True
                ),
                "step": P(),
                "good_steps": P(),
                "skipped_steps": P(),
            }
            state_shardings = shd.to_shardings(pspecs, mesh)
            batch_spec = input_specs(cfg, shape_name)
            batch_shardings = _batch_shardings(batch_spec, mesh, b)
            mb = max(1, cfg.microbatch)
            mb_spec = None
            if mb > 1:
                per_mb = b // mb
                bp = shd.batch_pspec(mesh, per_mb)
                mb_spec = jax.sharding.NamedSharding(mesh, P(None, *bp))
            step = make_train_step(cfg, microbatch_spec=mb_spec)
            lowered = jax.jit(
                step,
                in_shardings=(state_shardings, batch_shardings),
                out_shardings=(state_shardings, None),
                donate_argnums=(0,),
            ).lower(state_shape, batch_spec)
        elif sh["kind"] == "prefill":
            params_shape = jax.eval_shape(lambda: M.init_params(key, cfg))
            pshard = shd.to_shardings(shd.param_pspecs(params_shape, mesh), mesh)
            batch_spec = input_specs(cfg, shape_name)
            batch_shardings = _batch_shardings(batch_spec, mesh, b)
            cache_len = s + (cfg.num_patches if cfg.family == "vlm" else 0)
            cache_shape = jax.eval_shape(
                lambda p: M.init_cache(p, cfg, b, cache_len), params_shape
            )
            cache_shardings = shd.to_shardings(
                shd.cache_pspecs(cache_shape, mesh, b), mesh
            )

            def prefill_step(params, batch):
                return M.prefill(params, batch, cfg, max_len=cache_len)

            lowered = jax.jit(
                prefill_step,
                in_shardings=(pshard, batch_shardings),
                out_shardings=(None, cache_shardings),
            ).lower(params_shape, batch_spec)
        else:  # decode
            params_shape = jax.eval_shape(lambda: M.init_params(key, cfg))
            pshard = shd.to_shardings(shd.param_pspecs(params_shape, mesh), mesh)
            cache_len = s + (cfg.num_patches if cfg.family == "vlm" else 0)
            cache_shape = jax.eval_shape(
                lambda p: M.init_cache(p, cfg, b, cache_len), params_shape
            )
            cache_shardings = shd.to_shardings(
                shd.cache_pspecs(cache_shape, mesh, b), mesh
            )
            tok_spec = input_specs(cfg, shape_name)
            tok_shardings = _batch_shardings(tok_spec, mesh, b)

            def serve_step(params, cache, token, pos):
                return M.decode_step(params, cache, token, pos, cfg)

            lowered = jax.jit(
                serve_step,
                in_shardings=(pshard, cache_shardings,
                              tok_shardings["token"], tok_shardings["pos"]),
                out_shardings=(None, cache_shardings),
                donate_argnums=(1,),
            ).lower(params_shape, cache_shape, tok_spec["token"], tok_spec["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    report = analyze_compiled(compiled, mesh.devices.size)
    report.update(
        arch=arch, shape=shape_name, kind=sh["kind"],
        mesh=list(mesh.shape.values()), mesh_axes=list(mesh.axis_names),
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
    )
    if save_hlo:
        with open(save_hlo, "w") as fh:
            fh.write(compiled.as_text())
    return report


def _fmt(report):
    r = report["roofline"]
    mem = report.get("memory", {})
    return (
        f"{report['arch']:18s} {report['shape']:12s} mesh={report['mesh']} "
        f"flops/dev={report['flops_per_dev']:.3e} "
        f"peak_mem/dev={mem.get('peak_bytes', 0)/2**30:.2f}GiB "
        f"coll/dev={report['collectives']['total']/2**20:.1f}MiB "
        f"terms(c/m/n)=({r['compute_s']:.4f}/{r['memory_s']:.4f}/"
        f"{r['collective_s']:.4f})s dom={r['dominant']} "
        f"roofline={r['roofline_fraction']:.2f} "
        f"[lower {report['lower_s']}s compile {report['compile_s']}s]"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--set", default="", help="cfg overrides k=v,k=v (ints/floats/bools parsed)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set.split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                v = {"true": True, "false": False}.get(v.lower(), v)
        overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    todo = cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape in todo:
        for multi in meshes:
            mesh = make_production_mesh(multi_pod=multi)
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            try:
                hlo_path = (
                    os.path.join(args.out, tag + ".hlo.txt") if args.save_hlo else None
                )
                report = lower_cell(arch, shape, mesh, save_hlo=hlo_path,
                                    overrides=overrides)
                print(_fmt(report), flush=True)
                with open(os.path.join(args.out, tag + ".json"), "w") as fh:
                    json.dump(report, fh, indent=1)
            except Exception:
                failures += 1
                print(f"FAIL {tag}", flush=True)
                traceback.print_exc()
                with open(os.path.join(args.out, tag + ".FAILED"), "w") as fh:
                    fh.write(traceback.format_exc())
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
