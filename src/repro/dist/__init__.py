# Distribution layer: sharding rules (param/opt/cache PartitionSpecs) and
# gradient compression for the multi-host train/serve dry-runs.
