"""int8 compression for cross-replica sync and sharded-bundle shipping.

Two consumers of the same symmetric per-tensor int8 scheme:

  * **gradients** — classic EF-SGD: quantize (grad + carried error) to int8,
    all-reduce the small payload, and carry the quantization residual into
    the next step — the time-averaged applied update is unbiased (the
    residual telescopes).

  * **deployed KAN bundles** — checkpoint shipping for sharded deployments:
    :func:`compress_deployed_kan` GATHERS a (possibly mesh-sharded) bundle's
    padded weights to host and int8-compresses each leaf;
    :func:`decompress_deployed_kan` decodes and SCATTERS the payload back
    onto a target mesh via ``deployed_kan_pspecs`` — so a bundle placed on
    one mesh can ship as a ~4x-smaller payload and land on a different mesh
    (or none) at the receiving end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "init_error_feedback",
    "compressed_grad_sync",
    "compress_deployed_kan",
    "decompress_deployed_kan",
    "_quantize",
]


def _quantize(g: jax.Array):
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(g).max(), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_feedback(params):
    """Zero residual tree, shaped like the gradients."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grad_sync(grads, error_feedback, axis_name: str = "data"):
    """Quantize + psum-mean gradients inside a pmap/shard_map collective.

    Returns (synced_grads, new_error_feedback).  Call under a mapped axis
    named ``axis_name``; the int8 payload is what crosses the interconnect.
    """
    def one(g, e):
        q, s = _quantize(g.astype(jnp.float32) + e)
        deq = q.astype(jnp.float32) * s
        new_e = (g.astype(jnp.float32) + e) - deq
        synced = jax.lax.pmean(deq, axis_name)
        return synced, new_e

    pairs = jax.tree.map(one, grads, error_feedback)
    synced = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return synced, new_ef


# ----------------------------------------------------------------------------
# deployed-KAN bundle shipping (gather -> compress -> scatter)
# ----------------------------------------------------------------------------


def compress_deployed_kan(dep) -> dict:
    """Gather a deployed-KAN bundle to host and int8-compress its weights.

    Works on placed (mesh-sharded) and unplaced bundles alike —
    ``device_get`` reassembles sharded leaves to their global shape.  The
    shared SH-LUT ships in raw f32 (it is tiny and the whole datapath's
    precision anchor); the padded ``wc``/``wb`` matrices — the bulk of a
    bundle — ship as (int8 codes, f32 scale).  Returns a host-side payload
    dict for :func:`decompress_deployed_kan`.
    """
    import dataclasses

    layers = []
    for lw in dep.layers:
        entry = {}
        for k, leaf in lw.items():
            a = np.asarray(jax.device_get(leaf))
            if a.dtype == np.int8:
                # already int4-packed storage ("wcp"/"lutp"): ship verbatim
                entry[k] = a
            elif k.startswith("lut") or k == "wscale":
                # tiny precision anchors: raw f32
                entry[k] = np.asarray(a, np.float32)
            else:
                # pure host-side codec (numpy mirror of _quantize): the
                # gather already brought the leaf to host, so no device
                # round-trip
                a = np.asarray(a, np.float32)
                s = max(float(np.abs(a).max()), 1e-30) / 127.0
                q = np.clip(np.round(a / s), -127, 127).astype(np.int8)
                entry[k] = (q, float(s))
        layers.append(entry)
    return {
        "layers": layers,
        "dims": tuple(int(d) for d in dep.dims),
        "specs": tuple(dataclasses.astuple(s) for s in dep.specs),
        "residual_raw": bool(dep.residual_raw),
    }


def decompress_deployed_kan(payload: dict, dep, mesh=None):
    """Decode a compressed bundle and scatter it onto ``mesh``.

    ``dep`` supplies the geometry/specs template (the receiving end's
    ``DeployedKAN``, e.g. freshly deployed from the same quantized params);
    its weight values are replaced by the decoded payload.  With ``mesh``
    the decoded layers are placed per ``deployed_kan_pspecs`` and the
    returned bundle records the placement, so it executes sharded without
    further ceremony; ``mesh=None`` returns a host-resident bundle.
    """
    import dataclasses

    from ..core.kan_network_deploy import place_deployed_kan

    specs = tuple(dataclasses.astuple(s) for s in dep.specs)
    if (tuple(payload["dims"]) != tuple(dep.dims)
            or bool(payload["residual_raw"]) != bool(dep.residual_raw)
            or tuple(payload["specs"]) != specs):
        raise ValueError(
            f"payload geometry {payload['dims']} (residual_raw="
            f"{payload['residual_raw']}) does not match bundle {dep.dims} "
            f"(residual_raw={dep.residual_raw}) / its quantization specs"
        )
    layers = []
    for entry in payload["layers"]:
        lw = {}
        for k, v in entry.items():
            if isinstance(v, tuple):
                q, s = v
                lw[k] = jnp.asarray(q, jnp.float32) * jnp.float32(s)
            else:
                lw[k] = jnp.asarray(v)  # raw leaf, dtype preserved
        layers.append(lw)
    out = dataclasses.replace(dep, layers=tuple(layers), placement=None)
    if mesh is not None:
        out = place_deployed_kan(out, mesh)
    return out
