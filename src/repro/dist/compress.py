"""int8 gradient compression with error feedback for cross-replica sync.

Classic EF-SGD scheme: quantize (grad + carried error) to int8 with a
per-leaf symmetric scale, all-reduce the small payload, and carry the
quantization residual into the next step — the time-averaged applied update
is unbiased (the residual telescopes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_feedback", "compressed_grad_sync", "_quantize"]


def _quantize(g: jax.Array):
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(g).max(), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_feedback(params):
    """Zero residual tree, shaped like the gradients."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grad_sync(grads, error_feedback, axis_name: str = "data"):
    """Quantize + psum-mean gradients inside a pmap/shard_map collective.

    Returns (synced_grads, new_error_feedback).  Call under a mapped axis
    named ``axis_name``; the int8 payload is what crosses the interconnect.
    """
    def one(g, e):
        q, s = _quantize(g.astype(jnp.float32) + e)
        deq = q.astype(jnp.float32) * s
        new_e = (g.astype(jnp.float32) + e) - deq
        synced = jax.lax.pmean(deq, axis_name)
        return synced, new_e

    pairs = jax.tree.map(one, grads, error_feedback)
    synced = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return synced, new_ef
