"""Role-based sharding rules: param / optimizer / cache PartitionSpecs.

The rules are NAME-based (the param tree keys carry the role: wq/wk/wv have
their heads axis at index ndim-2, attention wo at ndim-3, ffn wi/wg shard
the hidden dim, embed shards the vocab) with a divisibility guard — a dim is
only sharded when the mesh axis divides it, otherwise the leaf stays
replicated on that axis.  Everything here returns plain PartitionSpec trees;
``to_shardings`` binds them to a mesh.

Scanned stacks put a leading repeats dim on every decoder leaf, so all index
rules count FROM THE END of the shape.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "param_pspecs",
    "opt_state_pspecs",
    "batch_pspec",
    "cache_pspecs",
    "paged_cache_pspecs",
    "deployed_kan_pspecs",
    "to_shardings",
]


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _path_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


# (predicate on path, index-from-end of the dim to put on "model")
_MODEL_RULES = [
    (lambda p: p.endswith("wq") or p.endswith("wk") or p.endswith("wv"), 2),
    (lambda p: ("attn/wo" in p) or ("xattn/wo" in p), 3),          # (H, hd, D)
    (lambda p: p.endswith("bq") or p.endswith("bk") or p.endswith("bv"), 2),
    (lambda p: p.endswith("ffn/wi") or p.endswith("ffn/wg"), 1),   # (D, F)
    (lambda p: p.endswith("ffn/wo"), 2),                           # (F, D)
    (lambda p: p.endswith("moe/wi") or p.endswith("moe/wg"), 1),   # (E, D, F)
    (lambda p: p.endswith("moe/wo"), 2),                           # (E, F, D)
    (lambda p: p.endswith("ffn/c1"), 1),   # KAN (D, G+K, H): shard hidden
    (lambda p: p.endswith("ffn/wb1"), 1),
    (lambda p: p.endswith("ffn/c2"), 3),   # (H, G+K, D): shard hidden
    (lambda p: p.endswith("ffn/wb2"), 2),
]


def _leaf_spec(path: str, shape, msize: int, dsize: int, fsdp: bool) -> P:
    nd = len(shape)
    parts = [None] * nd
    if nd == 0:
        return P()
    if path.endswith("embed"):
        # (V, D): vocab on "model" (the lm_head transpose shards likewise)
        if msize > 1 and shape[0] % msize == 0:
            parts[0] = "model"
    elif path.endswith("lm_head") or path.endswith("patch_proj"):
        if msize > 1 and shape[-1] % msize == 0:
            parts[-1] = "model"
    else:
        for pred, from_end in _MODEL_RULES:
            if pred(path) and nd >= from_end:
                dim = nd - from_end
                if msize > 1 and shape[dim] % msize == 0:
                    parts[dim] = "model"
                break
    if fsdp and dsize > 1:
        # ZeRO-3-style: fully shard the largest still-replicated dim on
        # "data" when it divides evenly (skip tiny dims - norm scales etc.)
        cands = [
            i for i in range(nd)
            if parts[i] is None and shape[i] % dsize == 0 and shape[i] >= 2 * dsize
        ]
        if cands:
            parts[max(cands, key=lambda i: shape[i])] = "data"
    return P(*parts)


def param_pspecs(params, mesh, fsdp: bool = False):
    """PartitionSpec tree for a models.model.init_params tree."""
    msize, dsize = _axis_size(mesh, "model"), _axis_size(mesh, "data")
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [
        _leaf_spec(_path_str(kp), getattr(leaf, "shape", ()), msize, dsize, fsdp)
        for kp, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_pspecs(opt_state, params, mesh, zero1: bool = True):
    """Optimizer-state specs: moment trees mirror the param layout.

    zero1 keeps the moments on the same spec as their param (the "data" axis
    placement already fully shards fsdp params; for replicated params the
    moments stay replicated — a conservative ZeRO-1 that never conflicts
    with the param's own axes).
    """
    pspecs = param_pspecs(params, mesh, fsdp=zero1)

    def one(entry):
        # entry is either a moment tree shaped like params or a scalar
        if isinstance(entry, dict) and set(entry) != set():
            leaves = jax.tree.leaves(entry)
            if leaves and len(leaves) == len(jax.tree.leaves(params)):
                return pspecs
        return jax.tree.map(lambda leaf: P(), entry)

    if isinstance(opt_state, dict):
        return {k: one(v) for k, v in opt_state.items()}
    return jax.tree.map(lambda _: P(), opt_state)


def batch_pspec(mesh, global_batch: int) -> P:
    """Batch-dim spec: shard over "data" when it divides; the tuple form is
    used when there is slack for further axes (super-batch > data size)."""
    dsize = _axis_size(mesh, "data")
    if dsize <= 1 or global_batch % dsize != 0:
        return P(None)
    if global_batch > dsize:
        return P(("data",))
    return P("data")


def cache_pspecs(cache, mesh, batch: int):
    """KV/recurrent cache specs: shard the batch dim on "data" if it divides."""
    dsize = _axis_size(mesh, "data")

    def one(leaf):
        shape = getattr(leaf, "shape", ())
        parts = [None] * len(shape)
        if dsize > 1 and batch % dsize == 0:
            for i, d in enumerate(shape):
                if d == batch:
                    parts[i] = "data"
                    break
        return P(*parts)

    return jax.tree.map(one, cache)


def paged_cache_pspecs(cache, mesh, num_blocks: int):
    """Paged KV pool specs: shard the pool (num_blocks) dim on "data" when
    it divides — the paged analogue of ``cache_pspecs``'s slot-dim rule.
    Leaves are (repeats, NB, block_size, H, D); the NB dim is matched by
    size, counting from index 1 so a repeats count equal to NB can't
    shadow it."""
    dsize = _axis_size(mesh, "data")

    def one(leaf):
        shape = getattr(leaf, "shape", ())
        parts = [None] * len(shape)
        if dsize > 1 and num_blocks % dsize == 0:
            for i, d in enumerate(shape):
                if i >= 1 and d == num_blocks:
                    parts[i] = "data"
                    break
        return P(*parts)

    return jax.tree.map(one, cache)


def deployed_kan_pspecs(dep, mesh):
    """PartitionSpecs for a ``repro.runtime`` deployed-KAN bundle's layers.

    The padded banded weights shard their OUTPUT-channel dim on "model"
    (each shard owns whole columns of the MAC — no cross-shard reduction,
    matching the per-output-channel quantization scales), the shared SH-LUT
    stays replicated.  Shardability is the runtime's criterion
    (``kernels.kan_spline.pipeline.model_shardable``: the axis divides the
    128-padded dim and each shard keeps a multiple-of-8 slab), so placement
    and sharded execution always agree — a layer the runtime would fall
    back to replicated is never placed sharded.
    """
    from ..kernels.kan_spline.pipeline import model_shardable

    msize = _axis_size(mesh, "model")

    def one_layer(lw):
        def col_spec(a):
            if model_shardable(int(a.shape[-1]), msize):
                return P(*([None] * (a.ndim - 1) + ["model"]))
            return P(*([None] * a.ndim))

        # key-generic over the deployed forms: SH-LUT leaves ("lut", and the
        # int4-packed "lutp") replicate; every other leaf — "wc", or the
        # packed "wcp" + per-channel "wscale" row, and "wb" — carries its
        # output channels on the last dim and shards them on "model"
        return {
            k: (P(*([None] * a.ndim)) if k.startswith("lut")
                else col_spec(a))
            for k, a in lw.items()
        }

    return tuple(one_layer(lw) for lw in dep.layers)


def to_shardings(pspecs, mesh):
    """Bind a PartitionSpec tree to a mesh as NamedShardings."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
