"""Deterministic, seekable synthetic token pipeline.

Production posture without external data: every batch is a pure function of
(seed, step, host_shard), so

  * **determinism**: restart at step K reproduces the exact stream (no data
    loss or duplication after checkpoint restore);
  * **host sharding**: each host materializes only its slice of the global
    batch (per-process loading on multi-host pods);
  * **packing**: documents of random length are packed into fixed seq_len
    rows with EOS separators, emulating a packed pretraining pipeline.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "global_batch_at_step", "host_batch_at_step"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 2
    mean_doc_len: int = 512


def _doc_stream(rng: np.random.Generator, n_tokens: int, cfg: DataConfig):
    """Markov-ish synthetic tokens packed with EOS boundaries."""
    out = np.empty(n_tokens, np.int32)
    i = 0
    while i < n_tokens:
        dlen = min(int(rng.exponential(cfg.mean_doc_len)) + 8, n_tokens - i)
        start = rng.integers(3, cfg.vocab_size)
        walk = rng.integers(-64, 65, size=dlen).cumsum() + start
        out[i : i + dlen] = np.clip(np.abs(walk) % cfg.vocab_size, 3, None)
        i += dlen
        if i < n_tokens:
            out[i] = cfg.eos_id
            i += 1
    return out


def global_batch_at_step(cfg: DataConfig, step: int):
    """The full (global_batch, seq_len) tokens/targets for one step."""
    rng = np.random.default_rng((cfg.seed, step))
    toks = _doc_stream(rng, cfg.global_batch * (cfg.seq_len + 1), cfg)
    toks = toks.reshape(cfg.global_batch, cfg.seq_len + 1)
    return {"tokens": toks[:, :-1].copy(), "targets": toks[:, 1:].copy()}


def host_batch_at_step(cfg: DataConfig, step: int, host_id: int, num_hosts: int):
    """Deterministic per-host slice (seek = just pass the step)."""
    assert cfg.global_batch % num_hosts == 0
    per = cfg.global_batch // num_hosts
    rng = np.random.default_rng((cfg.seed, step, host_id))
    toks = _doc_stream(rng, per * (cfg.seq_len + 1), cfg)
    toks = toks.reshape(per, cfg.seq_len + 1)
    return {"tokens": toks[:, :-1].copy(), "targets": toks[:, 1:].copy()}
