"""Synthetic knot-theory surrogate dataset: 17 features -> 14 classes.

The paper evaluates on the original KAN paper's knot-theory task (Davies et
al., Nature 2021: predict a knot's signature from 17 geometric/algebraic
invariants; the signature takes 14 distinct values in the dataset).  The real
dataset is not available offline, so we synthesize a *matched-difficulty
surrogate* with the property that makes KAN shine there: the target is an
ADDITIVE function of smooth 1-D nonlinear transforms of a few features (the
known result for the real task is that signature ~ slope + a couple of
invariants), plus distractor features and label noise tuned so a ~190k-param
MLP lands near the paper's 78% and small KANs can exceed it.

Deterministic given the seed; split sizes follow the original 17-in/14-class
setup.
"""

from __future__ import annotations

import numpy as np

__all__ = ["make_knot_dataset", "NUM_FEATURES", "NUM_CLASSES"]

NUM_FEATURES = 17
NUM_CLASSES = 14


def _smooth_1d(rng: np.random.Generator):
    """Random smooth bounded 1-D function (random low-order Fourier series).

    Frequencies are kept low (<= 1.5 periods over [-1, 1]) so a coarse-grid
    KAN can capture most of the structure — matching the smooth, mostly
    monotone invariant->signature relations of the real knot dataset — while
    the k=2,3 harmonics leave headroom that grid extension recovers.
    """
    n_terms = 3
    decay = np.arange(1, n_terms + 1) ** 2.5
    a = rng.normal(size=n_terms) / decay
    b = rng.normal(size=n_terms) / decay
    ph = rng.uniform(0, 2 * np.pi, size=n_terms)

    def f(x):
        y = np.zeros_like(x)
        for k in range(n_terms):
            w = (k + 1) * np.pi / 2.0
            y += a[k] * np.sin(w * x + ph[k]) + b[k] * np.cos(w * x)
        return y

    return f


def make_knot_dataset(
    n_train: int = 8192,
    n_test: int = 2048,
    seed: int = 0,
    label_noise: float = 0.12,
):
    """Returns (x_train, y_train, x_test, y_test); x in [-1, 1]^17."""
    rng = np.random.default_rng(seed)
    n = n_train + n_test
    # bell-shaped invariant distributions (paper Fig. 8 premise: central
    # B_i(X) fire most often), truncated to the KAN domain
    x = np.clip(rng.normal(0.0, 0.45, size=(n, NUM_FEATURES)), -1.0, 1.0)
    x = x.astype(np.float32)

    # signature ~ additive model over 5 "real" invariants (like slope,
    # meridinal/longitudinal translation in the Nature paper)
    informative = [0, 3, 5, 9, 14]
    weights = [1.0, 0.8, 0.7, 0.5, 0.4]
    fs = [_smooth_1d(rng) for _ in informative]
    score = np.zeros(n, dtype=np.float64)
    for w, f, j in zip(weights, fs, informative):
        score += w * f(x[:, j])
    # mild pairwise term so the task is not purely additive (keeps MLP in play)
    score += 0.15 * np.tanh(x[:, 0] * x[:, 5])
    score += label_noise * rng.normal(size=n)

    # class = binned score.  Signatures are even integers with most knots
    # near 0, i.e. UNBALANCED ordinal bins -> equal-width bins over +-2.2
    # score-sigmas (central classes carry most of the mass, like the real
    # Nature-2021 dataset), not balanced quantiles.
    mu, sd = score.mean(), score.std()
    edges = np.linspace(mu - 2.2 * sd, mu + 2.2 * sd, NUM_CLASSES + 1)[1:-1]
    y = np.digitize(score, edges).astype(np.int32)

    return (
        x[:n_train],
        y[:n_train],
        x[n_train:],
        y[n_train:],
    )
