"""jit'd wrapper for the cim_mac kernel: padding + tiling from flat (B, R)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cim_mac_pallas

__all__ = ["cim_mac"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("array_rows", "ir_scale", "adc_bits", "block_b", "block_c", "interpret"),
)
def cim_mac(
    x: jax.Array,   # (B, R_total) WL drives
    w: jax.Array,   # (R_total, C) weights
    *,
    array_rows: int,
    ir_scale: float,
    adc_bits: int,
    x_max: float,
    block_b: int = 128,
    block_c: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bsz, r_total = x.shape
    cols = w.shape[-1]
    n_arrays = -(-r_total // array_rows)
    rp = n_arrays * array_rows
    x_p = jnp.pad(x, ((0, 0), (0, rp - r_total)))
    w_p = jnp.pad(w, ((0, rp - r_total), (0, 0)))

    bb = min(block_b, _round_up(bsz, 8))
    bc = min(block_c, _round_up(cols, 128))
    bp, cp = _round_up(bsz, bb), _round_up(cols, bc)
    x_p = jnp.pad(x_p, ((0, bp - bsz), (0, 0))).reshape(bp, n_arrays, array_rows)
    w_t = w_p.reshape(n_arrays, array_rows, cols)

    # column load/full-scale on the REAL columns (normalizing over padded
    # zero columns would inflate the effective IR coefficient), then pad
    w_amax = jnp.maximum(jnp.abs(w_t).max(), 1e-9)
    col_load = jnp.einsum(
        "bar,arc->ac", x_p / x_max, jnp.abs(w_t) / w_amax
    ) / (array_rows * bsz)  # normalize by REAL batch (padded rows are zero)
    col_load = col_load / jnp.maximum(col_load.mean(), 1e-12)
    fs = jnp.maximum(x_max * jnp.abs(w_t).sum(axis=1), 1e-9)  # (A, C)
    col_load = jnp.pad(col_load, ((0, 0), (0, cp - cols)))
    fs = jnp.pad(fs, ((0, 0), (0, cp - cols)), constant_values=1.0)
    w_p = jnp.pad(w_t, ((0, 0), (0, 0), (0, cp - cols)))

    out = cim_mac_pallas(
        x_p, w_p, col_load, fs,
        ir_scale=ir_scale, adc_bits=adc_bits,
        block_b=bb, block_c=bc, interpret=interpret,
    )
    return out[:bsz, :cols]
