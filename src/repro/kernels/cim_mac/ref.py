"""Pure-jnp oracle for the cim_mac kernel: deterministic ACIM partial-sum path.

Mirrors core.cim.cim_matmul with deterministic=True (the stochastic noise is
added outside the kernel — it is elementwise on the per-array partials):

  per array a:  w_eff[r,c] = w[a,r,c] * (1 - ir_scale * dist[r] * load[a,c])
                partial[b,a,c] = sum_r x[b,a,r] * w_eff[r,c]
                partial = adc_quantize(partial, fs[a,c], adc_bits)
  out[b,c] = sum_a partial[b,a,c]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cim_mac_ref(
    x: jax.Array,        # (B, A, R) float WL drives
    w: jax.Array,        # (A, R, C) float weights
    col_load: jax.Array, # (A, C) normalized column current
    fs: jax.Array,       # (A, C) ADC full-scale per column
    ir_scale: float,
    adc_bits: int,
) -> jax.Array:
    _, _, rows = x.shape
    dist = (jnp.arange(rows, dtype=jnp.float32) + 1.0) / rows
    factor = jnp.clip(
        1.0 - ir_scale * dist[None, :, None] * col_load[:, None, :], 0.0, 1.0
    )
    partial = jnp.einsum(
        "bar,arc->bac", x.astype(jnp.float32), w.astype(jnp.float32) * factor
    )
    mean_dist = (rows + 1.0) / (2.0 * rows)
    comp = jnp.maximum(1.0 - ir_scale * mean_dist * col_load, 1e-3)
    partial = partial / comp[None]
    lsb = 2.0 * fs / (2**adc_bits)
    partial = jnp.clip(partial, -fs[None], fs[None])
    partial = jnp.round(partial / lsb[None]) * lsb[None]
    return partial.sum(axis=1)
