"""Pallas TPU kernel: tiled ACIM MAC with IR-drop + ADC quantization.

The simulator hot loop for Fig. 12/13-scale studies: one grid step processes
one (batch-tile x array x col-tile) cell; the array axis is the contraction —
per-array partial sums are IR-drop-attenuated, ADC-quantized, then
accumulated into the output tile.  The IR-drop factor is built in-register
from the row-distance iota and the per-(array, col) load — nothing besides
x/w tiles moves through HBM.

Block shapes: rows = the physical array height (128..1024) stays whole (it
is the analog summation — it cannot be split without changing semantics);
batch and column tiles are MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cim_mac_kernel(
    x_ref,      # (bB, 1, R)
    w_ref,      # (1, R, bC)
    load_ref,   # (1, bC)
    fs_ref,     # (1, bC)
    out_ref,    # (bB, bC)
    *,
    ir_scale: float,
    adc_bits: int,
):
    a_step = pl.program_id(2)
    x = x_ref[...][:, 0, :].astype(jnp.float32)        # (bB, R)
    w = w_ref[...][0].astype(jnp.float32)              # (R, bC)
    load = load_ref[...][0].astype(jnp.float32)        # (bC,)
    fs = fs_ref[...][0].astype(jnp.float32)            # (bC,)

    rows = w.shape[0]
    dist = (
        jax.lax.broadcasted_iota(jnp.float32, (rows, 1), 0) + 1.0
    ) / rows                                           # (R, 1)
    factor = jnp.clip(1.0 - ir_scale * dist * load[None, :], 0.0, 1.0)

    partial = jax.lax.dot_general(
        x, w * factor, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (bB, bC)

    # per-column digital compensation of the mean attenuation (see cim.py)
    mean_dist = (rows + 1.0) / (2.0 * rows)
    comp = jnp.maximum(1.0 - ir_scale * mean_dist * load, 1e-3)
    partial = partial / comp[None, :]

    lsb = 2.0 * fs / (2.0**adc_bits)                   # (bC,)
    partial = jnp.clip(partial, -fs[None, :], fs[None, :])
    partial = jnp.round(partial / lsb[None, :]) * lsb[None, :]

    @pl.when(a_step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(a_step > 0)
    def _accum():
        out_ref[...] += partial


def cim_mac_pallas(
    x: jax.Array,        # (B, A, R)
    w: jax.Array,        # (A, R, C)
    col_load: jax.Array, # (A, C)
    fs: jax.Array,       # (A, C)
    *,
    ir_scale: float,
    adc_bits: int,
    block_b: int = 128,
    block_c: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bsz, n_arrays, rows = x.shape
    cols = w.shape[-1]
    assert bsz % block_b == 0 and cols % block_c == 0

    grid = (bsz // block_b, cols // block_c, n_arrays)
    kernel = functools.partial(
        _cim_mac_kernel, ir_scale=ir_scale, adc_bits=adc_bits
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 1, rows), lambda i, j, a: (i, a, 0)),
            pl.BlockSpec((1, rows, block_c), lambda i, j, a: (a, 0, j)),
            pl.BlockSpec((1, block_c), lambda i, j, a: (a, j)),
            pl.BlockSpec((1, block_c), lambda i, j, a: (a, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_c), lambda i, j, a: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, cols), jnp.float32),
        interpret=interpret,
    )(x, w, col_load, fs)
