"""Fused multi-layer quantized KAN executor on the Pallas path.

The paper's hardware win comes from keeping the *whole* quantized datapath
(eq. (1)-(3): ASP PowerGap decode -> SH-LUT retrieval -> banded MAC) on the
accelerator.  ``kernel.py`` covers one layer; this module chains layers so
that activations stay **int codes** between layers instead of round-tripping
through dequantized f32 in Python:

  * each layer runs the same fused datapath as ``_kan_spline_kernel``;
  * the inter-layer boundary — tanh domain rescale followed by
    ``quantize_input`` re-coding (the TPU analogue of the paper's N:1 TMDV
    input generator feeding the next array) — is fused into the producing
    layer's kernel, executed once per output tile on the final contraction
    step;
  * the whole stack runs under ONE jit: no per-layer Python dispatch, no
    host sync, one padding plan for the entire network.

Geometry is described by a static, hashable :class:`PipelinePlan`:

  * batch is padded once to a multiple of ``bb``;
  * every inter-layer boundary dim is padded to a multiple of 128 (the
    producing layer's ``bo`` and the consuming layer's ``bf`` both divide it,
    so codes flow between kernels with NO reslicing);
  * padded weight rows/cols are zero, so padded lanes contribute nothing —
    the boundary requantizer maps their tanh(0) midpoint code to rows whose
    weights are zero in the next layer.

Two residual-branch flavors cover both deployment surfaces:

  * ``residual_raw=False`` (KAN stacks, ``core.kan_layer``): the ReLU branch
    reads ``relu(dequantize(codes))`` — bit-compatible with
    ``kan_layer_apply_quantized``.
  * ``residual_raw=True`` (KAN-FFN, ``core.kan_ffn_deploy``): the ReLU branch
    reads the RAW pre-squash activation (models/layers._kan_linear contract),
    which the previous layer emits as a second f32 output.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.asp_quant import ASPQuantSpec, lut_scale

__all__ = [
    "LayerPlan",
    "PipelinePlan",
    "make_pipeline_plan",
    "model_shardable",
    "normalize_tile_overrides",
    "shard_local_plan",
    "validate_plan",
    "weight_bits",
    "packs_weights",
    "packs_lut",
    "layer_weight_keys",
    "pad_layer_weights",
    "pack_layer_weights",
    "pack_lut",
    "unpacked_wc",
    "run_pipeline_layer",
    "kan_pipeline",
    "kan_pipeline_impl",
]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pow2_at_least(x: int, lo: int = 8, hi: int = 128) -> int:
    p = lo
    while p < min(x, hi):
        p *= 2
    return p


# ----------------------------------------------------------------------------
# Static geometry plan
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Static per-layer geometry + boundary behavior (hashable, jit-static)."""

    spec: ASPQuantSpec          # quantization grid of THIS layer's input
    next_spec: ASPQuantSpec | None  # None -> last layer (emit f32 only)
    f: int                      # logical input width
    o: int                      # logical output width
    fp: int                     # padded input width  (multiple of bf)
    op: int                     # padded output width (multiple of bo)
    bb: int
    bo: int
    bf: int
    residual_raw: bool          # ReLU branch source: raw f32 vs deq(codes)

    @property
    def emit_codes(self) -> bool:
        return self.next_spec is not None


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    b: int                      # logical batch
    bp: int                     # padded batch (multiple of layers[0].bb)
    layers: tuple               # tuple[LayerPlan, ...]


# VMEM working-set ceiling for the basis tile (bB, bF, G+K) f32; bf is halved
# until the tile fits.  4 MiB leaves room for the wc tile + double buffering
# inside the 16 MiB v5e budget (see kernel.py header for the full budget).
_BASIS_TILE_BUDGET = 4 * 1024 * 1024


def _is_pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


def normalize_tile_overrides(tile_overrides, n_layers: int) -> tuple | None:
    """Canonicalize tile overrides to a per-layer ((bb, bo, bf), ...) tuple.

    Accepts a single (bb, bo, bf) triple (broadcast to every layer) or a
    per-layer sequence of triples.  ``bb`` must agree across layers — the
    batch pad ``bp`` is shared by the whole stack.
    """
    if tile_overrides is None:
        return None
    ov = tuple(tile_overrides)
    if len(ov) == 3 and all(not hasattr(v, "__len__") for v in ov):
        ov = tuple((int(ov[0]), int(ov[1]), int(ov[2])) for _ in range(n_layers))
    else:
        ov = tuple((int(b), int(o), int(f)) for b, o, f in ov)
    if len(ov) != n_layers:
        raise ValueError(f"{len(ov)} tile overrides for {n_layers} layers")
    if len({b for b, _, _ in ov}) != 1:
        raise ValueError(f"per-layer bb must agree (shared batch pad): {ov}")
    return ov


def make_pipeline_plan(
    batch: int,
    dims: tuple,
    specs: tuple,
    *,
    residual_raw: bool = False,
    max_block_b: int = 128,
    max_block_f: int = 128,
    tile_overrides=None,
) -> PipelinePlan:
    """Choose block sizes + padded dims for a whole stack from shapes alone.

    dims: (F0, O0=F1, O1=F2, ...) — len(dims) == n_layers + 1.
    specs: per-layer ASPQuantSpec, len == n_layers.

    ``tile_overrides`` (from ``repro.tune.tiles`` / the plan cache's tuned
    registry) replaces the heuristic block sizes with explicit per-layer
    ``(bb, bo, bf)`` triples.  Overrides change ONLY the tiling of the grid,
    never the padded dims ``fp``/``op`` — deployed weight bundles padded
    under the heuristic plan stay valid verbatim under any tuned plan, and
    the 128-padded inter-layer boundary contract is untouched.  Invalid
    overrides (non-power-of-two, not dividing the padded dim, basis tile
    over the VMEM budget) raise ``ValueError``.
    """
    n_layers = len(dims) - 1
    if len(specs) != n_layers:
        raise ValueError(f"{len(specs)} specs for {n_layers} layers")
    overrides = normalize_tile_overrides(tile_overrides, n_layers)

    bb = min(max_block_b, _round_up(batch, 8))
    if overrides is not None:
        bb = overrides[0][0]
        if bb < 8 or bb % 8:
            raise ValueError(f"bb override must be a multiple of 8 >= 8: {bb}")
        bb = min(bb, _round_up(batch, 8))
    bp = _round_up(batch, bb)

    layers = []
    for li in range(n_layers):
        f, o = dims[li], dims[li + 1]
        spec = specs[li]
        nb = spec.num_basis
        # bf must divide the boundary pad (128) when fed by a previous layer,
        # so it is a power of two <= 128; shrink until the basis tile fits.
        # The budget uses the WORST-CASE bb (max_block_b), not the actual bb,
        # so fp/op are batch-independent and DeployedKAN.replan can swap the
        # batch without re-padding weights.
        bf = _pow2_at_least(f) if li == 0 else 128
        while bf > 8 and max_block_b * bf * nb * 4 > _BASIS_TILE_BUDGET:
            bf //= 2
        bo = 128
        fp = _round_up(f, bf) if li == 0 else _round_up(f, 128)
        op = _round_up(o, bo)
        if overrides is not None:
            _, bo_c, bf_c = overrides[li]
            if not (_is_pow2(bo_c) and 8 <= bo_c <= 128 and op % bo_c == 0):
                raise ValueError(
                    f"layer {li}: bo override {bo_c} invalid for op={op}"
                )
            if not (_is_pow2(bf_c) and 8 <= bf_c <= 128 and fp % bf_c == 0):
                raise ValueError(
                    f"layer {li}: bf override {bf_c} invalid for fp={fp}"
                )
            if bb * bf_c * nb * 4 > _BASIS_TILE_BUDGET:
                raise ValueError(
                    f"layer {li}: basis tile {bb}x{bf_c}x{nb} exceeds the "
                    "VMEM budget"
                )
            bo, bf = bo_c, bf_c
        layers.append(
            LayerPlan(
                spec=spec,
                next_spec=specs[li + 1] if li + 1 < n_layers else None,
                f=f, o=o, fp=fp, op=op,
                bb=bb, bo=bo, bf=bf,
                residual_raw=residual_raw,
            )
        )
    return PipelinePlan(b=batch, bp=bp, layers=tuple(layers))


def model_shardable(op: int, model_size: int) -> bool:
    """Can an output dim split over a model axis of this size?

    The axis must divide the padded dim AND each shard must keep a
    multiple-of-8 slab (the smallest valid output tile).  This is THE
    shardability criterion — ``shard_local_plan`` (execution) and
    ``dist.sharding.deployed_kan_pspecs`` (weight placement) both use it,
    so a bundle is never placed sharded where the runtime would execute it
    replicated (or vice versa).
    """
    return (model_size > 1 and op % model_size == 0
            and (op // model_size) % 8 == 0)


def shard_local_plan(plan: PipelinePlan, model_size: int) -> tuple:
    """Per-shard geometry for output-channel ("model") sharding of a stack.

    Each model shard owns WHOLE output columns of every sharded layer (no
    cross-shard reduction in the MAC — the contraction axis stays full), so
    the per-shard plan keeps ``f``/``fp``/``bf`` and divides ``op`` by the
    model-axis size.  A layer is shardable when the axis divides its padded
    output dim AND the per-shard slab still admits a valid output tile
    (``op/model_size`` a multiple of 8); otherwise the layer FALLS BACK to
    replicated columns and the reason is recorded.  ``bo`` is halved until it
    divides the per-shard slab, so tuned tile plans (repro.tune.tiles) stay
    valid per-shard wherever they still divide.

    Returns ``(local_plan, sharded_flags, notes)``: the per-shard plan (its
    per-layer ``o``/``op`` are the LOCAL padded widths for sharded layers —
    logical-column slicing happens globally, after the gather), one bool per
    layer, and human-readable fallback reasons.

    The local plan intentionally violates two :func:`validate_plan`
    invariants — the inter-layer boundary (``fp`` stays full while the
    previous ``op`` is local: an all-gather over "model" restores the full
    width between layers) and the 128-padded-boundary rule (the 128 pad is a
    GLOBAL property; each shard holds a power-of-two fraction of it) — so it
    must not be re-validated.
    """
    n = len(plan.layers)
    if model_size <= 1:
        return plan, (False,) * n, ()
    layers, flags, notes = [], [], []
    for li, lp in enumerate(plan.layers):
        if not model_shardable(lp.op, model_size):
            notes.append(
                f"layer {li}: op={lp.op} not shardable over model={model_size}"
                " (needs a multiple-of-8 per-shard slab); columns replicated"
            )
            layers.append(lp)
            flags.append(False)
            continue
        op_l = lp.op // model_size
        bo_l = lp.bo
        while op_l % bo_l:
            bo_l //= 2
        layers.append(dataclasses.replace(lp, o=op_l, op=op_l, bo=bo_l))
        flags.append(True)
    return (
        dataclasses.replace(plan, layers=tuple(layers)),
        tuple(flags),
        tuple(notes),
    )


def validate_plan(plan: PipelinePlan) -> None:
    """Assert every geometric invariant the fused executor relies on.

    Raises ``ValueError`` on the first violation.  Used by the tile
    autotuner to reject candidate geometries before they are ever compiled,
    and by the tests as the single source of truth for plan validity.
    """
    if not plan.layers:
        raise ValueError("plan has no layers")
    if plan.bp < plan.b:
        raise ValueError(f"padded batch {plan.bp} < logical batch {plan.b}")
    prev_op = None
    for li, lp in enumerate(plan.layers):
        nb = lp.spec.num_basis
        if plan.bp % lp.bb:
            raise ValueError(f"layer {li}: bp={plan.bp} not divisible by bb={lp.bb}")
        if lp.fp % lp.bf:
            raise ValueError(f"layer {li}: fp={lp.fp} not divisible by bf={lp.bf}")
        if lp.op % lp.bo:
            raise ValueError(f"layer {li}: op={lp.op} not divisible by bo={lp.bo}")
        if lp.fp < lp.f or lp.op < lp.o:
            raise ValueError(f"layer {li}: padded dims below logical dims")
        if prev_op is not None and lp.fp != prev_op:
            raise ValueError(
                f"layer {li}: boundary mismatch fp={lp.fp} != prev op={prev_op}"
            )
        if lp.emit_codes and lp.op % 128:
            raise ValueError(
                f"layer {li}: boundary op={lp.op} not 128-padded"
            )
        if lp.bb * lp.bf * nb * 4 > _BASIS_TILE_BUDGET:
            raise ValueError(
                f"layer {li}: basis tile {lp.bb}x{lp.bf}x{nb} exceeds the "
                "VMEM budget"
            )
        prev_op = lp.op


def pad_layer_weights(wc: jax.Array, wb: jax.Array, lp: LayerPlan) -> dict:
    """Zero-pad one layer's dequantized weights to the plan's geometry.

    wc: (F, G+K, O) -> (Fp * (G+K), Op) flattened banded matrix.
    wb: (F, O)      -> (Fp, Op).
    """
    nb = lp.spec.num_basis
    wc_p = jnp.pad(
        wc.astype(jnp.float32), ((0, lp.fp - lp.f), (0, 0), (0, lp.op - lp.o))
    ).reshape(lp.fp * nb, lp.op)
    wb_p = jnp.pad(
        wb.astype(jnp.float32), ((0, lp.fp - lp.f), (0, lp.op - lp.o))
    )
    return {"wc": wc_p, "wb": wb_p}


# ----------------------------------------------------------------------------
# Sub-8-bit packing (KANtize-style mixed precision)
# ----------------------------------------------------------------------------
#
# A layer whose weight codes fit in 4 bits stores them PACKED: two signed
# int4 row-codes per int8 lane along the contraction axis (row 2r in the low
# nibble, row 2r+1 in the high nibble), plus the per-output-channel f32
# scales — the f32 banded matrix is never materialized at rest, halving the
# layer's weight residency.  The kernel unpacks inside the banded-MAC
# contraction with int32 shift arithmetic and multiplies by the scale row in
# f32 — the exact product the unpacked deployment stores — so packed and
# unpacked executions are bit-identical.  A <=4-bit SH-LUT likewise packs
# two unsigned nibbles per lane along the K+1 axis.


def weight_bits(spec: ASPQuantSpec) -> int:
    """Signed weight-code width a layer deploys at (input width, capped 8)."""
    return min(8, spec.n_bits)


def packs_weights(spec: ASPQuantSpec) -> bool:
    """True when the layer's weight codes int4-pack (two per int8 lane)."""
    return weight_bits(spec) <= 4


def packs_lut(spec: ASPQuantSpec) -> bool:
    """True when the layer's SH-LUT codes int4-pack."""
    return spec.lut_bits <= 4


def layer_weight_keys(lp: LayerPlan) -> tuple:
    """The deployed weight-dict keys this layer's plan implies.

    The mesh runner and ``dist.sharding`` derive their per-leaf
    PartitionSpecs from these (keys starting with "lut" replicate;
    everything else shards its output-channel dim on "model").
    """
    keys = ["lut"]
    if packs_lut(lp.spec):
        keys.append("lutp")
    if packs_weights(lp.spec):
        keys += ["wcp", "wscale"]
    else:
        keys.append("wc")
    keys.append("wb")
    return tuple(keys)


def _pack_nibbles(lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Pair two int code arrays into one int8 lane (lo nibble, hi nibble)."""
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)
    return (((hi << 4) & 0xF0) | (lo & 0x0F)).astype(jnp.int8)


def _unpack_lo_nibble(p32: jax.Array) -> jax.Array:
    """Sign-extended low nibble of packed int8 lanes (as int32)."""
    return jax.lax.shift_right_arithmetic(jax.lax.shift_left(p32, 28), 28)


def _unpack_hi_nibble(p32: jax.Array) -> jax.Array:
    """Sign-extended high nibble of packed int8 lanes (as int32)."""
    return jax.lax.shift_right_arithmetic(jax.lax.shift_left(p32, 24), 28)


def pack_layer_weights(c_q: jax.Array, c_scale: jax.Array,
                       wb: jax.Array, lp: LayerPlan) -> dict:
    """int4-pack one layer's spline weight CODES to the plan's geometry.

    c_q: int8 (F, G+K, O) signed codes in [-7, 7] -> "wcp" (Fp*(G+K)//2, Op)
    with consecutive contraction rows paired per lane; c_scale: (O,) ->
    "wscale" (1, Op) f32 (padded channels scale 0, so every padded lane
    still decodes to exactly 0); wb stays dequantized f32 (it is the small
    residual branch), zero-padded as in :func:`pad_layer_weights`.
    """
    nb = lp.spec.num_basis
    q = jnp.pad(
        jnp.asarray(c_q, jnp.int8),
        ((0, lp.fp - lp.f), (0, 0), (0, lp.op - lp.o)),
    ).reshape(lp.fp * nb, lp.op)
    wcp = _pack_nibbles(q[0::2], q[1::2])
    wscale = jnp.pad(
        jnp.asarray(c_scale, jnp.float32), (0, lp.op - lp.o)
    )[None, :]
    wb_p = jnp.pad(
        wb.astype(jnp.float32), ((0, lp.fp - lp.f), (0, lp.op - lp.o))
    )
    return {"wcp": wcp, "wscale": wscale, "wb": wb_p}


def pack_lut(lut_q: jax.Array, spec: ASPQuantSpec) -> jax.Array:
    """Pack the (2**LD, K+1) unsigned SH-LUT codes two-per-lane on K+1.

    Odd K+1 pads one zero column before pairing; the kernel unpacks and
    slices back to K+1.  Codes are unsigned nibbles (lut_bits <= 4).
    """
    kk = spec.order + 1
    q = jnp.asarray(lut_q, jnp.int32)
    if kk % 2:
        q = jnp.pad(q, ((0, 0), (0, 1)))
    return _pack_nibbles(q[:, 0::2], q[:, 1::2])


def unpacked_wc(lw: dict, lp: LayerPlan) -> jax.Array:
    """The padded f32 banded matrix of a deployed layer, packed or not.

    For packed layers this reproduces the kernel's in-lane decode
    arithmetic exactly (int32 nibble extract -> f32 code x f32 scale), so
    jnp consumers (the ref composition, the acim backend's w_lsb / IR-drop
    paths, bundle compression) see bit-identical weight values.
    """
    if "wc" in lw:
        return lw["wc"].astype(jnp.float32)
    p32 = lw["wcp"].astype(jnp.int32)
    half, op = lw["wcp"].shape
    q = jnp.stack(
        [_unpack_lo_nibble(p32), _unpack_hi_nibble(p32)], axis=1
    ).reshape(2 * half, op)
    return q.astype(jnp.float32) * lw["wscale"].astype(jnp.float32)


# ----------------------------------------------------------------------------
# The fused per-layer kernel (single-layer datapath + fused boundary requant)
# ----------------------------------------------------------------------------


def _pipeline_layer_kernel(
    *refs,
    lp: LayerPlan,
    has_psum_noise: bool = False,
    packed_w: bool = False,
    packed_lut: bool = False,
):
    """One KAN layer tile + (optionally) the fused inter-layer requantizer.

    Ref order: codes, [xraw], lut | lutp, wc | (wcp, wscale), wb,
    [psum_noise], y_out, [codes_out].
    Grid: (Bp/bb, Op/bo, Fp/bf); the F axis (last) is the contraction —
    y_out accumulates across it, the boundary fires on the final step.

    ``packed_w`` / ``packed_lut`` (static, from the deployed dict's keys):
    the weight / LUT operand arrives as two int4 codes per int8 lane and is
    unpacked HERE, inside the contraction — int32 nibble extract, then
    f32 code x f32 scale, the exact product the unpacked deployment stores,
    so the packed MAC is bit-identical to the unpacked one.

    ``psum_noise`` is the ACIM backend's hook: a precomputed (bb, bo) f32
    perturbation (the macro's partial-sum error, already scaled for the
    number of physical arrays this column spans) folded into the
    accumulator on the first contraction step — so the fused boundary
    requantizer sees the NOISY pre-activation and the error propagates
    through the int-code stream exactly as it would on silicon.
    """
    idx = 0
    codes_ref = refs[idx]; idx += 1
    xraw_ref = None
    if lp.residual_raw:
        xraw_ref = refs[idx]; idx += 1
    lut_ref = refs[idx]; idx += 1
    wc_ref = refs[idx]; idx += 1
    wscale_ref = None
    if packed_w:
        wscale_ref = refs[idx]; idx += 1
    wb_ref = refs[idx]; idx += 1
    noise_ref = None
    if has_psum_noise:
        noise_ref = refs[idx]; idx += 1
    y_ref = refs[idx]; idx += 1
    codes_out_ref = refs[idx] if lp.emit_codes else None

    spec = lp.spec
    k_step = pl.program_id(2)
    n_k = pl.num_programs(2)
    nb = spec.num_basis
    kk = spec.order + 1
    n_local = spec.codes_per_interval

    codes = codes_ref[...]
    bb, bf = codes.shape

    # --- PowerGap bit split (VPU shift/mask; the "decoder" is free)
    g = jax.lax.shift_right_logical(codes, spec.ld)
    local = jax.lax.bitwise_and(codes, n_local - 1)

    if packed_lut:
        # two unsigned LUT nibbles per lane along K+1: decode with the
        # trace-time scale constant (== the deployed f32 table's scale)
        p32 = lut_ref[...].astype(jnp.int32)
        lo_n = jax.lax.bitwise_and(p32, 0xF)
        hi_n = jax.lax.bitwise_and(
            jax.lax.shift_right_logical(p32, 4), 0xF
        )
        lut_tile = jnp.stack([lo_n, hi_n], axis=-1).reshape(
            n_local, 2 * p32.shape[1]
        )[:, :kk].astype(jnp.float32) * jnp.float32(lut_scale(spec))
    else:
        lut_tile = lut_ref[...].astype(jnp.float32)

    # --- SH-LUT retrieval as one-hot matmul (2**LD is tiny: <= 32)
    flat_local = local.reshape(bb * bf, 1)
    iota_l = jax.lax.broadcasted_iota(jnp.int32, (bb * bf, n_local), 1)
    onehot = (iota_l == flat_local).astype(jnp.float32)
    lutv = jax.lax.dot_general(
        onehot,
        lut_tile,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bb, bf, kk)

    # --- banded placement: basis[b, f, i] = lutv[b, f, i - g] if 0<=i-g<=K
    iota_nb = jax.lax.broadcasted_iota(jnp.int32, (bb, bf, nb), 2)
    d = iota_nb - g[..., None]
    basis = jnp.zeros((bb, bf, nb), jnp.float32)
    for dd in range(kk):  # static unroll: K+1 selects
        basis = basis + jnp.where(d == dd, lutv[..., dd][..., None], 0.0)

    # --- spline MAC on the MXU
    if packed_w:
        # unpack two signed int4 row-codes per lane: row 2r from the low
        # nibble, row 2r+1 from the high nibble, interleaved back into
        # contraction order, then decoded against the per-channel scales
        p32 = wc_ref[...].astype(jnp.int32)
        half, bo_w = p32.shape
        wq = jnp.stack(
            [_unpack_lo_nibble(p32), _unpack_hi_nibble(p32)], axis=1
        ).reshape(2 * half, bo_w)
        wc_tile = wq.astype(jnp.float32) * wscale_ref[...].astype(jnp.float32)
    else:
        wc_tile = wc_ref[...].astype(jnp.float32)
    acc = jax.lax.dot_general(
        basis.reshape(bb, bf * nb),
        wc_tile,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # --- fused residual branch
    if lp.residual_raw:
        resid = xraw_ref[...].astype(jnp.float32)
    else:
        resid = spec.lo + codes.astype(jnp.float32) * spec.code_step
    acc = acc + jax.lax.dot_general(
        jnp.maximum(resid, 0.0),
        wb_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == 0)
    def _init():
        if noise_ref is not None:
            y_ref[...] = acc + noise_ref[...]
        else:
            y_ref[...] = acc

    @pl.when(k_step > 0)
    def _accum():
        y_ref[...] += acc

    if lp.emit_codes:
        nxt = lp.next_spec
        half_span = 0.5 * (nxt.hi - nxt.lo)
        mid = 0.5 * (nxt.hi + nxt.lo)
        scale = 1.0 / nxt.code_step

        @pl.when(k_step == n_k - 1)
        def _requant():
            # the fused boundary: tanh domain rescale -> ASP re-coding.
            # Ops mirror core.kan_layer.kan_network_apply +
            # core.asp_quant.quantize_input exactly (bit-exact contract).
            h = jnp.tanh(y_ref[...]) * half_span + mid
            q = jnp.floor((h - nxt.lo) * scale + 0.5).astype(jnp.int32)
            codes_out_ref[...] = jnp.clip(q, 0, nxt.num_codes - 1)


def _run_layer(
    codes: jax.Array,       # (Bp, Fp) int32
    xraw: jax.Array | None,  # (Bp, Fp) f32, only when lp.residual_raw
    lw: dict,               # deployed layer weights (packed or unpacked)
    lp: LayerPlan,
    bp: int,
    *,
    interpret: bool,
    psum_noise: jax.Array | None = None,  # (Bp, Op) f32 (acim backend)
):
    spec = lp.spec
    nb = spec.num_basis
    # packing is a property of the weights ACTUALLY handed in (dict keys are
    # static pytree structure): the acim backend's IR-drop path substitutes
    # an unpacked f32 dict for a packed layer and the kernel follows.
    packed_w = "wcp" in lw
    packed_lut = "lutp" in lw
    assert codes.shape == (bp, lp.fp), (codes.shape, bp, lp.fp)
    if packed_w:
        assert lw["wcp"].shape == (lp.fp * nb // 2, lp.op), (
            lw["wcp"].shape, lp.fp, nb, lp.op)
    else:
        assert lw["wc"].shape == (lp.fp * nb, lp.op), (
            lw["wc"].shape, lp.fp, nb, lp.op)

    grid = (bp // lp.bb, lp.op // lp.bo, lp.fp // lp.bf)

    in_specs = [pl.BlockSpec((lp.bb, lp.bf), lambda i, j, k: (i, k))]
    inputs = [codes]
    if lp.residual_raw:
        in_specs.append(pl.BlockSpec((lp.bb, lp.bf), lambda i, j, k: (i, k)))
        inputs.append(xraw)
    if packed_lut:
        kk_half = (spec.order + 1 + 1) // 2
        in_specs.append(pl.BlockSpec(
            (spec.codes_per_interval, kk_half), lambda i, j, k: (0, 0)
        ))
        inputs.append(lw["lutp"])
    else:
        in_specs.append(pl.BlockSpec(
            (spec.codes_per_interval, spec.order + 1), lambda i, j, k: (0, 0)
        ))
        inputs.append(lw["lut"])
    if packed_w:
        # bf >= 8 keeps bf*nb even, so every contraction block owns whole
        # packed lanes and the (k, j) index map stays contiguous
        in_specs += [
            pl.BlockSpec((lp.bf * nb // 2, lp.bo), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, lp.bo), lambda i, j, k: (0, j)),
        ]
        inputs += [lw["wcp"], lw["wscale"]]
    else:
        in_specs.append(pl.BlockSpec((lp.bf * nb, lp.bo), lambda i, j, k: (k, j)))
        inputs.append(lw["wc"])
    in_specs.append(pl.BlockSpec((lp.bf, lp.bo), lambda i, j, k: (k, j)))
    inputs.append(lw["wb"])
    if psum_noise is not None:
        assert psum_noise.shape == (bp, lp.op), (psum_noise.shape, bp, lp.op)
        in_specs.append(pl.BlockSpec((lp.bb, lp.bo), lambda i, j, k: (i, j)))
        inputs.append(psum_noise)

    out_specs = [pl.BlockSpec((lp.bb, lp.bo), lambda i, j, k: (i, j))]
    out_shape = [jax.ShapeDtypeStruct((bp, lp.op), jnp.float32)]
    if lp.emit_codes:
        out_specs.append(pl.BlockSpec((lp.bb, lp.bo), lambda i, j, k: (i, j)))
        out_shape.append(jax.ShapeDtypeStruct((bp, lp.op), jnp.int32))

    kernel = functools.partial(
        _pipeline_layer_kernel, lp=lp, has_psum_noise=psum_noise is not None,
        packed_w=packed_w, packed_lut=packed_lut,
    )
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    if lp.emit_codes:
        return outs[0], outs[1]
    return outs[0], None


# Public name for the single-layer step: the mesh-sharded runtime composes
# layers itself (it needs an all-gather between them), so it drives the same
# fused kernel one layer at a time instead of through kan_pipeline_impl.
run_pipeline_layer = _run_layer


# ----------------------------------------------------------------------------
# The multi-layer executor: unjitted body + the single-jit entry point
# ----------------------------------------------------------------------------


def kan_pipeline_impl(
    codes: jax.Array,        # (B, F0) int32 — entry activation codes
    xraw: jax.Array | None,  # (B, F0) f32 raw entry input (residual_raw only)
    layers: tuple,           # per-layer dicts: {"lut", "wc", "wb"} PADDED
    plan: PipelinePlan,
    *,
    interpret: bool = False,
    psum_noises: tuple | None = None,  # per-layer (Bp, Op) f32 or None (acim)
    return_intermediates: bool = False,
):
    """Unjitted pipeline body: plan application split from jit dispatch.

    ``repro.runtime`` backends wrap this under their own per-cache-entry
    jit — the pallas backend calls it as-is, the acim backend pre-transforms
    the weights (IR-drop) and threads per-layer ``psum_noises`` into the MAC
    stage, so non-ideality injection never forks the kernel itself.
    """
    lp0 = plan.layers[0]
    b = codes.shape[0]
    assert b == plan.b, (b, plan.b)
    codes = jnp.pad(codes, ((0, plan.bp - b), (0, lp0.fp - lp0.f)))
    if lp0.residual_raw:
        # padded raw lanes are zero: relu(0) @ zero-padded wb rows == 0
        xraw = jnp.pad(
            xraw.astype(jnp.float32), ((0, plan.bp - b), (0, lp0.fp - lp0.f))
        )

    h_codes, h_raw = codes, xraw
    y = None
    boundary_codes = []
    for li, (lp, lw) in enumerate(zip(plan.layers, layers)):
        noise = psum_noises[li] if psum_noises is not None else None
        y, nxt_codes = _run_layer(
            h_codes,
            h_raw if lp.residual_raw else None,
            lw, lp, plan.bp,
            interpret=interpret,
            psum_noise=noise,
        )
        if nxt_codes is not None:
            boundary_codes.append(nxt_codes[: plan.b, : lp.o])
        h_codes, h_raw = nxt_codes, y
    out = y[: plan.b, : plan.layers[-1].o]
    if return_intermediates:
        return out, tuple(boundary_codes)
    return out


@functools.partial(
    jax.jit, static_argnames=("plan", "interpret", "return_intermediates")
)
def kan_pipeline(
    codes: jax.Array,
    xraw: jax.Array | None,
    layers: tuple,
    plan: PipelinePlan,
    *,
    interpret: bool = False,
    return_intermediates: bool = False,
):
    """Run the whole quantized KAN stack on the Pallas path under one jit.

    Between layers only int32 activation codes move (plus the raw f32
    activation when ``residual_raw``); the final layer returns f32 logits
    sliced back to the logical (B, O_last) shape.

    With ``return_intermediates`` also returns the int32 boundary codes each
    layer handed to the next (sliced to logical shapes) — the conformance
    tests assert these are bit-identical to the layered reference's
    re-quantization.

    This is the standalone entry point; the serving/deploy surfaces go
    through ``repro.runtime``, which wraps :func:`kan_pipeline_impl` in
    per-bucket cached jits instead.
    """
    return kan_pipeline_impl(
        codes, xraw, layers, plan,
        interpret=interpret, return_intermediates=return_intermediates,
    )
