"""jit'd public wrapper around the kan_spline Pallas kernel.

Handles padding to block multiples (padded F rows get zero weights so their
basis contribution vanishes; padded B rows are sliced off; padded O columns
are sliced off) and exposes a convenience entry point that consumes the
qparams dict produced by core.kan_layer.quantize_kan_layer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.asp_quant import ASPQuantSpec
from .kernel import kan_spline_pallas

__all__ = ["kan_spline", "kan_spline_from_qparams"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("spec", "block_b", "block_o", "block_f", "interpret"),
)
def kan_spline(
    codes: jax.Array,   # (B, F) int32
    lut: jax.Array,     # (2**LD, K+1)
    wc: jax.Array,      # (F, G+K, O)
    wb: jax.Array,      # (F, O)
    spec: ASPQuantSpec,
    *,
    block_b: int = 128,
    block_o: int = 128,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bsz, f = codes.shape
    o = wc.shape[-1]
    nb = spec.num_basis

    bb = min(block_b, _round_up(bsz, 8))
    bo = min(block_o, _round_up(o, 128))
    bf = min(block_f, _round_up(f, 8))

    bp, fp, op = _round_up(bsz, bb), _round_up(f, bf), _round_up(o, bo)
    codes_p = jnp.pad(codes, ((0, bp - bsz), (0, fp - f)))
    wc_p = jnp.pad(wc, ((0, fp - f), (0, 0), (0, op - o))).reshape(fp * nb, op)
    wb_p = jnp.pad(wb, ((0, fp - f), (0, op - o)))

    out = kan_spline_pallas(
        codes_p, lut, wc_p, wb_p, spec,
        block_b=bb, block_o=bo, block_f=bf, interpret=interpret,
    )
    return out[:bsz, :o]


def kan_spline_from_qparams(
    codes: jax.Array, qparams: dict, spec: ASPQuantSpec, *, interpret: bool = False
) -> jax.Array:
    """Run the kernel from quantize_kan_layer output (dequantized weights)."""
    wc = qparams["c_q"].astype(jnp.float32) * qparams["c_scale"]
    wb = qparams["w_b_q"].astype(jnp.float32) * qparams["w_b_scale"]
    return kan_spline(codes, qparams["lut"], wc, wb, spec, interpret=interpret)
