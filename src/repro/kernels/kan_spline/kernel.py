"""Pallas TPU kernel: fused ASP spline-basis construction + banded matmul.

TPU-native realization of the paper's B(X) datapath (DESIGN.md §2):

  * PowerGap bit split -> shift/mask on the VPU (replaces silicon decoders)
  * SH-LUT retrieval   -> one-hot x (2**LD, K+1) matmul (replaces TG-MUXes;
                          no per-element dynamic gather touches HBM)
  * banded basis placement -> iota-compare/select against the interval index
  * spline MAC         -> dense (bB, bF*(G+K)) x (bF*(G+K), bO) on the MXU
                          ("B(X) on word lines x c' in the RRAM array")
  * the w_b * relu(x) residual branch is fused into the same tile

Grid: (B/bB, O/bO, F/bF); the F axis is the contraction — partial products
accumulate into the output tile (revisited across the last grid dimension,
per the TPU grid-iteration guarantee).

VMEM per step ~ bB*bF*4 (codes) + 2**LD*(K+1)*4 (LUT) + bF*NB*bO*4 (wc tile)
+ bB*NB*bF*4 (basis tile) + bB*bO*4 (acc): with bB=bO=128, bF=256, NB=8 the
working set is ~3.3 MiB — inside the 16 MiB v5e VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.asp_quant import ASPQuantSpec


def _kan_spline_kernel(
    codes_ref,  # (bB, bF) int32
    lut_ref,    # (2**LD, K+1) f32
    wc_ref,     # (bF * NB, bO) f32/bf16
    wb_ref,     # (bF, bO) f32/bf16
    out_ref,    # (bB, bO) f32
    *,
    spec: ASPQuantSpec,
    block_f: int,
):
    k_step = pl.program_id(2)
    nb = spec.num_basis
    kk = spec.order + 1
    n_local = spec.codes_per_interval

    codes = codes_ref[...]
    bb, bf = codes.shape

    # --- PowerGap bit split (VPU shift/mask; the "decoder" is free)
    g = jax.lax.shift_right_logical(codes, spec.ld)          # interval index
    local = jax.lax.bitwise_and(codes, n_local - 1)          # offset in interval

    # --- SH-LUT retrieval as one-hot matmul (2**LD is tiny: <= 32)
    flat_local = local.reshape(bb * bf, 1)
    iota_l = jax.lax.broadcasted_iota(jnp.int32, (bb * bf, n_local), 1)
    onehot = (iota_l == flat_local).astype(jnp.float32)
    lutv = jax.lax.dot_general(
        onehot,
        lut_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bb, bf, kk)                                    # (bB, bF, K+1)

    # --- banded placement: basis[b, f, i] = lutv[b, f, i - g] if 0<=i-g<=K
    iota_nb = jax.lax.broadcasted_iota(jnp.int32, (bb, bf, nb), 2)
    d = iota_nb - g[..., None]
    basis = jnp.zeros((bb, bf, nb), jnp.float32)
    for dd in range(kk):  # static unroll: K+1 selects
        basis = basis + jnp.where(d == dd, lutv[..., dd][..., None], 0.0)

    # --- spline MAC on the MXU
    acc = jax.lax.dot_general(
        basis.reshape(bb, bf * nb),
        wc_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # --- fused residual branch: relu(deq(codes)) @ wb
    xdeq = spec.lo + codes.astype(jnp.float32) * spec.code_step
    acc = acc + jax.lax.dot_general(
        jnp.maximum(xdeq, 0.0),
        wb_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(k_step > 0)
    def _accum():
        out_ref[...] += acc


def kan_spline_pallas(
    codes: jax.Array,   # (B, F) int32
    lut: jax.Array,     # (2**LD, K+1)
    wc: jax.Array,      # (F * NB, O)  — flattened (f, i) rows
    wb: jax.Array,      # (F, O)
    spec: ASPQuantSpec,
    *,
    block_b: int = 128,
    block_o: int = 128,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call; caller guarantees divisibility (see ops.py)."""
    bsz, f = codes.shape
    o = wc.shape[-1]
    nb = spec.num_basis
    assert wc.shape[0] == f * nb, (wc.shape, f, nb)
    assert bsz % block_b == 0 and o % block_o == 0 and f % block_f == 0

    grid = (bsz // block_b, o // block_o, f // block_f)
    kernel = functools.partial(_kan_spline_kernel, spec=spec, block_f=block_f)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_f), lambda i, j, k: (i, k)),
            pl.BlockSpec(
                (spec.codes_per_interval, spec.order + 1), lambda i, j, k: (0, 0)
            ),
            pl.BlockSpec((block_f * nb, block_o), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_f, block_o), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, o), jnp.float32),
        interpret=interpret,
    )(codes, lut, wc, wb)
