"""Pure-jnp oracle for the fused ASP KAN-spline kernel.

Computes, for quantized input codes (B, F):

    basis[b, f, i] = SH-LUT value of B_i at code[b, f]   (i in [0, G+K))
    y[b, o] = sum_{f,i} basis[b,f,i] * wc[f,i,o]  +  relu(deq(code[b,f])) * wb[f,o]

This is the composition of asp_quant.dense_basis_from_codes with the banded
matmul — the bit-exact contract the Pallas kernel is validated against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.asp_quant import ASPQuantSpec, dense_basis_from_codes


def kan_spline_ref(
    codes: jax.Array,   # (B, F) int32 in [0, G*2**LD)
    lut: jax.Array,     # (2**LD, K+1) float
    wc: jax.Array,      # (F, G+K, O) spline coefficients (c')
    wb: jax.Array,      # (F, O) residual-branch weights
    spec: ASPQuantSpec,
) -> jax.Array:
    basis = dense_basis_from_codes(codes, lut, spec)  # (B, F, G+K)
    bsz, f, nb = basis.shape
    o = wc.shape[-1]
    y = basis.reshape(bsz, f * nb).astype(jnp.float32) @ wc.reshape(f * nb, o).astype(
        jnp.float32
    )
    xdeq = spec.lo + codes.astype(jnp.float32) * spec.code_step
    y = y + jax.nn.relu(xdeq) @ wb.astype(jnp.float32)
    return y
