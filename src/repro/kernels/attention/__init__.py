"""Fused flash-attention Pallas kernel (the "flash" runtime attention
backend): online-softmax tiled-KV SDPA, GQA-aware, masks built from
positions.  See :mod:`repro.kernels.attention.kernel` for the kernel and
:mod:`repro.kernels.attention.ops` for the model-facing wrapper."""

from .kernel import NEG_INF, flash_attention_fused
from .ops import flash_attention

__all__ = ["NEG_INF", "flash_attention", "flash_attention_fused"]
