"""Shape/layout wrapper for the fused flash-attention kernel.

``flash_attention`` accepts the model-facing GQA layout used across
:mod:`repro.models.layers` — q: (B, S, Hq, D), k/v: (B, T, Hkv, D) — folds
each KV head's query group next to the query rows, pads S and T to tile
multiples (padded positions carry -1, so the kernel masks them and emits
exact zeros for padded query rows), runs the Pallas kernel and slices the
result back.  Off-TPU the kernel executes in interpret mode automatically.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .kernel import KINDS, flash_attention_fused

__all__ = ["flash_attention"]


def _default_interpret() -> bool:
    # same probe as runtime.default_interpret(), duplicated locally so the
    # kernels package stays import-independent of the runtime package
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _positions(p, b: int, n: int, offset: int):
    """Normalize a position operand to (B, n) int32; None = arange+offset."""
    if p is None:
        p = jnp.arange(n, dtype=jnp.int32) + offset
    p = jnp.asarray(p, jnp.int32)
    if p.ndim == 1:
        p = p[None]
    return jnp.broadcast_to(p, (b, n))


def flash_attention(q, k, v, *, kind: str = "causal", qpos=None, kpos=None,
                    window: int = 0, softcap: float = 0.0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """Memory-efficient fused attention over GQA layouts.

    Args:
      q: (B, S, Hq, D); k, v: (B, T, Hkv, D) with Hq % Hkv == 0.
      kind: "causal" (kpos <= qpos), "local" (causal AND
        kpos > qpos - window), or "full" (no positional mask).
      qpos / kpos: int32 absolute positions, shaped (S,)/(B, S) resp.
        (T,)/(B, T).  None means contiguous right-aligned positions
        (``arange(S) + (T - S)`` / ``arange(T)`` — the `_sdpa` defaults).
        Negative kpos marks an invalid key (unwritten rolling-cache slot)
        and is masked under every kind; query rows whose mask ends up empty
        (e.g. negative qpos padding) return exactly 0.
      window: sliding-window size for kind="local" (<= 0 disables it).
      softcap: logit soft-cap, applied before masking (0 disables).
      scale: logit scale; defaults to 1/sqrt(D).
      interpret: run the Pallas kernel in interpret mode; None = auto
        (True off-TPU).

    Returns (B, S, Hq, D) in q's dtype.
    """
    if kind not in KINDS:
        raise ValueError(f"kind {kind!r} not in {KINDS}")
    if interpret is None:
        interpret = _default_interpret()
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    g = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    qpos = _positions(qpos, b, s, offset=t - s)
    kpos = _positions(kpos, b, t, offset=0)

    bq = min(block_q, _round_up(s, 8))
    bk = min(block_k, _round_up(t, 8))
    sp, tp = _round_up(s, bq), _round_up(t, bk)

    # (B, S, Hq, D) -> (B, Hkv, S, G, D): group rides next to the query rows
    qr = q.reshape(b, s, hkv, g, d).transpose(0, 2, 1, 3, 4)
    kr = k.transpose(0, 2, 1, 3)
    vr = v.transpose(0, 2, 1, 3)
    if sp != s:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, sp - s), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, sp - s)), constant_values=-1)
    if tp != t:
        kr = jnp.pad(kr, ((0, 0), (0, 0), (0, tp - t), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, 0), (0, tp - t), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, tp - t)), constant_values=-1)

    out = flash_attention_fused(
        qr, kr, vr, qpos, kpos, kind=kind, window=window, softcap=softcap,
        scale=scale, block_q=bq, block_k=bk, interpret=interpret,
    )
    out = out[:, :, :s]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, s, hq, d)
