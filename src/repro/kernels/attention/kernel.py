"""Flash-attention Pallas kernel: online softmax over tiled KV streaming.

One fused kernel computes ``softmax(q k^T * scale + mask) v`` without ever
materializing the (S, T) score matrix: the KV sequence is streamed in tiles
along the innermost (sequential) grid dimension while VMEM scratch carries
the per-query-row running maximum ``m``, running denominator ``l`` and the
rescaled output accumulator — the FlashAttention recurrence (Dao et al.).

Layout/grid conventions (the :mod:`.ops` wrapper produces these):

  * q:    (B, Hkv, S, G, D) — the GQA query group G is folded next to the
          query rows, so ONE KV head tile streamed from HBM serves its whole
          group; in-kernel the q tile is reshaped to (block_q * G, D) rows.
  * k, v: (B, Hkv, T, D)
  * qpos/kpos: (B, S) / (B, T) int32 absolute positions.  Negative kpos
          marks an invalid key (unwritten rolling-cache slot, padded tile) —
          masked under EVERY kind; negative qpos rows finalize to exact 0.
  * grid: (B, Hkv, S/block_q, T/block_k) with the KV tile index innermost —
          scratch persists across the sequential KV sweep, is initialized at
          the first tile and finalized (guarded division) at the last.

Masks are built on the fly from the position vectors — no (S, T) tensor —
matching the ``layers._sdpa_chunk`` semantics and its ``-1e30`` constant:

  * "causal": kpos <= qpos
  * "local":  causal AND kpos > qpos - window (sliding window)
  * "full":   no positional mask (bidirectional / cross attention)

The denominator is guarded at finalization: rows with no valid key emit
exactly 0 instead of a uniform average over masked garbage (the decode
padding bug this kernel's ref path also fixes).  All arithmetic is f32;
the output is cast back to the query dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # finite mask constant shared with layers.py (no NaN risk)
_STAT_LANES = 128  # running m/l scratch is lane-replicated for TPU tiling

KINDS = ("causal", "local", "full")


def _flash_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, out_ref,
                  m_scr, l_scr, acc_scr, *,
                  kind: str, window: int, softcap: float, scale: float):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    bq, g, d = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
    bk = k_ref.shape[2]
    q = q_ref[0, 0].reshape(bq * g, d).astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    if softcap > 0.0:
        # softcap BEFORE masking — same op order as layers._sdpa_chunk
        s = jnp.tanh(s / softcap) * softcap

    qp = qpos_ref[0]                     # (bq,) int32
    kp = kpos_ref[0]                     # (bk,) int32
    mask = (kp >= 0)[None, :]            # key validity, every kind
    if kind in ("causal", "local"):
        mask = mask & (kp[None, :] <= qp[:, None])
    if kind == "local" and window > 0:
        mask = mask & (kp[None, :] > qp[:, None] - window)
    # (bq, bk) -> (bq*G, bk): the positional mask is per-KV-head, shared by
    # the whole query group
    mask = jnp.broadcast_to(mask[:, None, :], (bq, g, bk)).reshape(bq * g, bk)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]                # (bq*G, 1)
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # masked lanes would exp to 1 when the whole tile is masked
    # (s == m_new == NEG_INF); the where keeps them at exactly 0
    e = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)      # rescale factor for the old state
    l_new = l_prev * alpha + jnp.sum(e, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        e, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        # guarded denominator: fully-masked rows (padded queries, qpos < 0)
        # emit exact zeros instead of an average over garbage
        out = jnp.where(l > 0.0, acc_scr[...] / jnp.maximum(l, 1e-30), 0.0)
        out_ref[0, 0] = out.reshape(bq, g, d).astype(out_ref.dtype)


def flash_attention_fused(q, k, v, qpos, kpos, *, kind: str, window: int,
                          softcap: float, scale: float, block_q: int,
                          block_k: int, interpret: bool):
    """The raw pallas_call on pre-tiled operands (see module docstring for
    the layout contract).  S must divide by block_q and T by block_k —
    :func:`repro.kernels.attention.ops.flash_attention` pads and slices."""
    if kind not in KINDS:
        raise ValueError(f"kind {kind!r} not in {KINDS}")
    b, hkv, s, g, d = q.shape
    t = k.shape[2]
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    grid = (b, hkv, s // block_q, t // block_k)
    kern = functools.partial(
        _flash_kernel, kind=kind, window=int(window),
        softcap=float(softcap), scale=float(scale),
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, g, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, block_q), lambda ib, ih, iq, ik: (ib, iq)),
            pl.BlockSpec((1, block_k), lambda ib, ih, iq, ik: (ib, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, g, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, s, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, _STAT_LANES), jnp.float32),  # running m
            pltpu.VMEM((block_q * g, _STAT_LANES), jnp.float32),  # running l
            pltpu.VMEM((block_q * g, d), jnp.float32),            # output acc
        ],
        interpret=interpret,
    )(q, k, v, qpos, kpos)
