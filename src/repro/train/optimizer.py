"""Optimizers in plain JAX: AdamW, Adafactor, SGD-momentum.

Each optimizer is (init_fn, update_fn) over arbitrary pytrees:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Adafactor implements factored second moments (Shazeer & Stern 2018) so the
405B-class configs keep optimizer bytes sublinear in the largest matrices —
the state for an (n, m) matrix is an (n,) row factor + (m,) column factor.
ZeRO-1 sharding of the state is applied at the sharding-rule layer
(dist/sharding.py), not here.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "sgdm", "apply_updates", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ----------------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------------


def adamw(
    lr: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def lr_at(step):
        return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], gf)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], gf)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_at(step)

        def upd(m_, v_, p):
            u = -(lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps))
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


# ----------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum by default)
# ----------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor(
    lr: float | Callable[[jax.Array], jax.Array],
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> Optimizer:
    def lr_at(step):
        return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

    def init_leaf(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(init_leaf, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        lr_t = lr_at(step)

        def upd(g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)[..., None]
                prec = (vr[..., None] * vc[..., None, :]) / jnp.maximum(denom, eps)
                u = g / jnp.sqrt(jnp.maximum(prec, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, new_s

        flat_u, flat_s = [], []
        leaves, treedef = jax.tree.flatten(params)
        gleaves = treedef.flatten_up_to(grads)
        sleaves = treedef.flatten_up_to(state["v"])
        for g, s in zip(gleaves, sleaves):
            u, ns = upd(g, s)
            flat_u.append(u)
            flat_s.append(ns)
        updates = jax.tree.unflatten(treedef, flat_u)
        new_v = jax.tree.unflatten(treedef, flat_s)
        return updates, {"step": step, "v": new_v}

    return Optimizer(init, update)


# ----------------------------------------------------------------------------
# SGD with momentum
# ----------------------------------------------------------------------------


def sgdm(lr: float, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        del params
        m = jax.tree.map(
            lambda m_, g: momentum * m_ + g.astype(jnp.float32), state["m"], grads
        )
        updates = jax.tree.map(lambda m_: -lr * m_, m)
        return updates, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)
