"""Training driver: sharded step, checkpoint/restart, straggler watchdog.

Fault-tolerance posture (1000+-node design, exercised here on the CPU mesh):

  * checkpoint/restart — atomic keep-N checkpoints (checkpoint.py); restore
    picks up at the exact data step (the pipeline is seekable), under ANY
    mesh shape (elastic re-shard on load).
  * NaN/Inf step rejection inside the compiled step (train_state.py).
  * straggler mitigation — a watchdog thread flags steps exceeding
    ``deadline_factor`` x the rolling median step time; on real fleets this
    feeds the controller that triggers hot-spare swap-in; here it logs and
    counts (hook point kept deliberately narrow so the compiled path is
    unchanged).
  * graceful preemption — SIGTERM sets a flag; the loop checkpoints and
    exits at the next step boundary.
"""

from __future__ import annotations

import signal
import threading
import time
from typing import Callable

import jax
import numpy as np

from ..configs.base import ModelConfig
from ..data.lm_data import DataConfig, global_batch_at_step
from .checkpoint import Checkpointer
from .train_state import init_state, make_train_step

__all__ = ["TrainLoop", "StepWatchdog"]


class StepWatchdog:
    """Flags steps that exceed deadline_factor x rolling-median duration."""

    def __init__(self, deadline_factor: float = 3.0, window: int = 32):
        self.deadline_factor = deadline_factor
        self.durations: list[float] = []
        self.window = window
        self.straggler_steps = 0

    def observe(self, dt: float) -> bool:
        hist = self.durations[-self.window:]
        is_straggler = bool(
            len(hist) >= 8 and dt > self.deadline_factor * float(np.median(hist))
        )
        self.durations.append(dt)
        if is_straggler:
            self.straggler_steps += 1
        return is_straggler


class TrainLoop:
    def __init__(
        self,
        cfg: ModelConfig,
        data_cfg: DataConfig,
        ckpt_dir: str,
        seed: int = 0,
        keep: int = 3,
        ckpt_every: int = 50,
        shardings: dict | None = None,
    ):
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.ckpt = Checkpointer(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.watchdog = StepWatchdog()
        self.shardings = shardings
        self._stop = threading.Event()

        key = jax.random.PRNGKey(seed)
        self.state = init_state(key, cfg)
        restored, step = self.ckpt.restore_latest(
            self.state,
            shardings=shardings.get("state") if shardings else None,
        )
        if restored is not None:
            self.state = restored
            self.start_step = int(step)
        else:
            self.start_step = 0

        step_fn = make_train_step(cfg)
        if shardings:
            self.step_fn = jax.jit(
                step_fn,
                in_shardings=(shardings["state"], shardings["batch"]),
                out_shardings=(shardings["state"], None),
                donate_argnums=(0,),
            )
        else:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0,))

    def install_sigterm_handler(self):
        signal.signal(signal.SIGTERM, lambda *_: self._stop.set())

    def run(self, num_steps: int, log_every: int = 10, log: Callable = print):
        metrics_hist = []
        for step in range(self.start_step, self.start_step + num_steps):
            if self._stop.is_set():
                log(f"[preempt] checkpointing at step {step} and exiting")
                self.ckpt.save(step, self.state, blocking=True)
                break
            batch = global_batch_at_step(self.data_cfg, step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])  # blocks; also the sync point
            dt = time.perf_counter() - t0
            if self.watchdog.observe(dt):
                log(f"[straggler] step {step} took {dt:.3f}s "
                    f"(median {np.median(self.watchdog.durations[-32:]):.3f}s)")
            metrics_hist.append({"step": step, "loss": loss, "time_s": dt})
            if step % log_every == 0:
                log(f"step {step}: loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, self.state)
        self.ckpt.wait()
        return metrics_hist
