"""Fault-tolerant checkpointing: atomic, async, keep-N, elastic restore.

Layout:  <dir>/step_<N>/  {manifest.json, <leaf-id>.npy ...}

* **Atomic**: written to ``step_<N>.tmp-<pid>`` then os.rename'd — a crash
  mid-write never leaves a readable-but-corrupt checkpoint directory.
* **Async**: arrays are device_get'd synchronously (cheap host copy), file
  IO happens on a daemon thread; ``wait()`` joins before the next save.
* **Keep-N**: oldest complete checkpoints beyond ``keep`` are deleted.
* **Elastic**: leaves are stored UNSHARDED (logical arrays), so a restore
  may apply ANY new mesh/sharding — checkpoints are mesh-shape-agnostic
  (restore_with_shardings re-device_puts under the new rules).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["Checkpointer", "save_pytree", "load_pytree", "latest_step"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(tree, path: str):
    """Synchronous atomic save of one pytree to ``path`` (a directory)."""
    leaves, treedef = _flatten(tree)
    tmp = f"{path}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"treedef": str(treedef), "num_leaves": len(leaves), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
        manifest["leaves"].append(
            {"i": i, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic publish


def load_pytree(path: str, like):
    """Restore into the structure of ``like`` (names/ordering must match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    assert manifest["num_leaves"] == len(leaves), "structure mismatch"
    out = [np.load(os.path.join(path, f"leaf_{i}.npy")) for i in range(len(leaves))]
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp") and "tmp-" not in d:
            try:
                steps.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, blocking: bool = False):
        """Host-copies now; writes on a background thread."""
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        host_tree = jax.tree_util.tree_unflatten(treedef, host_leaves)
        path = os.path.join(self.directory, f"step_{step}")

        def work():
            save_pytree(host_tree, path)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def restore_latest(self, like, shardings=None):
        """Returns (tree, step) or (None, None).  With ``shardings`` (a pytree
        of jax.sharding.Sharding) leaves are device_put under the NEW mesh —
        the elastic-rescale path."""
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        tree = load_pytree(os.path.join(self.directory, f"step_{step}"), like)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree, step

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and "tmp" not in d
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)
