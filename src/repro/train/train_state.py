"""TrainState pytree + builders for the sharded train step."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import init_params, loss_fn
from .optimizer import Optimizer, adafactor, adamw, apply_updates, clip_by_global_norm, sgdm

Params = Any


def make_optimizer(cfg: ModelConfig) -> Optimizer:
    if cfg.optimizer == "adamw":
        return adamw(cfg.learning_rate, weight_decay=0.1)
    if cfg.optimizer == "adafactor":
        return adafactor(cfg.learning_rate)
    if cfg.optimizer == "sgdm":
        return sgdm(cfg.learning_rate)
    raise ValueError(cfg.optimizer)


def init_state(key, cfg: ModelConfig):
    params = init_params(key, cfg)
    opt = make_optimizer(cfg)
    return {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
        "good_steps": jnp.zeros((), jnp.int32),   # NaN-guard accounting
        "skipped_steps": jnp.zeros((), jnp.int32),
    }


def make_train_step(cfg: ModelConfig, grad_clip: float = 1.0,
                    microbatch_spec=None):
    """Returns train_step(state, batch) -> (state, metrics).

    * gradient accumulation over cfg.microbatch microbatches (lax.scan so the
      HLO stays one microbatch body — the accumulation loop IS the remat
      boundary for the 405B-class memory footprint);
    * global-norm clipping;
    * NaN/Inf step rejection (fault tolerance: a poisoned batch must not
      corrupt the weights — the update is skipped and counted).
    """
    opt = make_optimizer(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch, cfg)

    def _constrain_mb(xs):
        """Keep the per-microbatch batch dim sharded over (pod, data).

        Without this, reshaping (B, S) -> (mb, B/mb, S) with mb smaller than
        the data axis makes XLA replicate each microbatch across data shards
        (measured 16x compute waste on qwen/phi3 train — EXPERIMENTS.md §Perf).
        The caller (launch/dryrun, launch/train) passes the NamedSharding or
        PartitionSpec for the reshaped (mb, B/mb, ...) layout.
        """
        if microbatch_spec is None:
            return xs
        return jax.lax.with_sharding_constraint(xs, microbatch_spec)

    def train_step(state, batch):
        params = state["params"]
        mb = max(1, cfg.microbatch)
        if mb > 1:
            def split(x):
                b = x.shape[0]
                xs = x.reshape((mb, b // mb) + x.shape[1:])
                return _constrain_mb(xs)

            mbatches = jax.tree.map(split, batch)

            def body(acc, mbatch):
                loss, grads = grads_of(params, mbatch)
                acc_loss, acc_grads = acc
                return (
                    acc_loss + loss,
                    jax.tree.map(jnp.add, acc_grads, grads),
                ), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, grad_sum), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero), mbatches
            )
            loss = loss_sum / mb
            grads = jax.tree.map(lambda g: g / mb, grad_sum)
        else:
            loss, grads = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, new_opt = opt.update(grads, state["opt"], params)

        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)

        def guarded(u):
            return jnp.where(ok, u, jnp.zeros_like(u))

        new_params = apply_updates(params, jax.tree.map(guarded, updates))
        new_opt = jax.tree.map(
            lambda new, old: jnp.where(ok, new, old), new_opt, state["opt"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
            "good_steps": state["good_steps"] + ok.astype(jnp.int32),
            "skipped_steps": state["skipped_steps"] + (~ok).astype(jnp.int32),
        }
        metrics = {"loss": loss, "grad_norm": gnorm, "ok": ok}
        return new_state, metrics

    return train_step
