"""KAN layers: float reference path and the ASP-quantized LUT path.

A KAN layer (paper eq. (1)-(3)) maps in_dim -> out_dim through per-edge
learnable 1-D functions::

    y_o = sum_f [ w_b[f,o] * relu(x_f) + sum_i c'[f,i,o] * B_i(x_f) ]

* ``b(x)`` is ReLU (the paper replaces SiLU "for improved hardware efficiency
  without accuracy loss").
* ``c' = w_s * c`` is fused (eq. (3)) and, on the quantized path, stored as
  int8 per-output-channel symmetric — this is what lives in the RRAM cells /
  on TPU in the banded weight matrix.
* The spline term is evaluated as a dense banded matmul
  ``basis (B, F*(G+K)) @ Wc (F*(G+K), O)`` — the MXU-native mapping of
  "B(X) on word lines x c' in the array".

Parameters are plain dict pytrees (jit/pjit friendly, no framework deps).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .asp_quant import (
    ASPQuantSpec,
    build_lut,
    dense_basis_from_codes,
    quantize_input,
    resolve_layer_bits,
)
from .bspline import bspline_basis

__all__ = [
    "KANSpec",
    "init_kan_layer",
    "kan_layer_apply",
    "quantize_kan_layer",
    "kan_layer_apply_quantized",
    "init_kan_network",
    "kan_network_apply",
    "refit_layer_spec",
    "extend_layer_grid",
    "param_count",
]


@dataclasses.dataclass(frozen=True)
class KANSpec:
    """Architecture of a KAN stack: dims + per-layer quantization specs.

    ``n_bits`` is either one int (uniform precision, the paper's deployment)
    or a per-layer tuple of widths (KANtize-style mixed precision) — one
    entry per layer, each independently PowerGap-validated.  A layer's
    ``lut_bits`` is clipped to its input width, so a 4-bit layer stores a
    4-bit SH-LUT (and the kernel packs two LUT/weight codes per int8 lane,
    see ``kernels.kan_spline.pipeline``).
    """

    dims: tuple  # e.g. (17, 1, 14)
    grid_size: int = 5
    order: int = 3
    n_bits: int | tuple = 8
    lut_bits: int = 8
    lo: float = -1.0
    hi: float = 1.0

    def __post_init__(self):
        if not isinstance(self.n_bits, int):
            object.__setattr__(
                self, "n_bits", tuple(int(b) for b in self.n_bits)
            )
        # validate eagerly: an invalid per-layer allocation must fail at
        # construction, not at first deploy
        self.layer_bits

    @property
    def layer_bits(self) -> tuple:
        """Per-layer input bit widths, PowerGap-validated (never clamped)."""
        return resolve_layer_bits(
            self.n_bits, len(self.dims) - 1, self.grid_size
        )

    def layer_spec(self, li: int = 0) -> ASPQuantSpec:
        b = self.layer_bits[li]
        return ASPQuantSpec(
            grid_size=self.grid_size,
            order=self.order,
            n_bits=b,
            lut_bits=min(self.lut_bits, b),
            lo=self.lo,
            hi=self.hi,
        )

    def layer_specs(self) -> tuple:
        return tuple(
            self.layer_spec(li) for li in range(len(self.dims) - 1)
        )

    @property
    def num_basis(self) -> int:
        return self.grid_size + self.order


def init_kan_layer(key, in_dim: int, out_dim: int, spec: ASPQuantSpec, dtype=jnp.float32):
    """c: (in, G+K, out) small-noise init (pykan-style); w_b: (in, out)."""
    kc, kb = jax.random.split(key)
    nb = spec.num_basis
    c = jax.random.normal(kc, (in_dim, nb, out_dim), dtype) * (0.1 / np.sqrt(in_dim))
    w_b = jax.random.normal(kb, (in_dim, out_dim), dtype) * (1.0 / np.sqrt(in_dim))
    return {"c": c, "w_b": w_b}


def _spline_matmul(basis: jax.Array, c: jax.Array) -> jax.Array:
    """(B, F, G+K) x (F, G+K, O) -> (B, O) as a single flattened matmul."""
    bsz = basis.shape[:-2]
    f, nb, o = c.shape
    lhs = basis.reshape(bsz + (f * nb,))
    rhs = c.reshape(f * nb, o)
    return lhs @ rhs


def kan_layer_apply(params, x: jax.Array, spec: ASPQuantSpec) -> jax.Array:
    """Float reference path (training path): Cox-de Boor basis, exact."""
    basis = bspline_basis(x, spec.lo, spec.hi, spec.grid_size, spec.order)
    y = _spline_matmul(basis, params["c"])
    y = y + jax.nn.relu(x) @ params["w_b"]
    return y


# ----------------------------------------------------------------------------
# Quantized inference path (ASP-KAN-HAQ)
# ----------------------------------------------------------------------------


def quantize_kan_layer(params, spec: ASPQuantSpec, weight_bits: int | None = None):
    """Post-training quantization of one layer.

    ``weight_bits`` sets the signed weight-code width (symmetric, per output
    channel): ``qmax = 2**(bits-1) - 1`` (127 at the default 8 bits, 7 at 4).
    ``None`` derives it from the layer's input width — ``min(8, spec.n_bits)``
    — so a 4-bit layer stores 4-bit weight codes the fused kernel packs two
    per int8 lane.

    Returns dict:
      c_q: int8 (in, G+K, out), symmetric per-output-channel.
      c_scale: (out,) float32.
      w_b_q / w_b_scale: same scheme for the residual-branch weights.
      lut: (2**LD, K+1) float32 dequantized SH-LUT values.
      lut_q / lut_scale / hemi: quantized table + physical hemi storage.
    """
    entry = build_lut(spec)
    if weight_bits is None:
        weight_bits = min(8, spec.n_bits)
    qmax = 2 ** (int(weight_bits) - 1) - 1
    c = np.asarray(params["c"], np.float64)
    w_b = np.asarray(params["w_b"], np.float64)

    def chan_q(w, axis_out):
        s = np.maximum(np.abs(w).max(axis=tuple(i for i in range(w.ndim) if i != axis_out)), 1e-12) / qmax
        q = np.clip(np.round(w / s), -qmax, qmax).astype(np.int8)
        return q, s.astype(np.float32)

    c_q, c_scale = chan_q(c, c.ndim - 1)
    w_b_q, w_b_scale = chan_q(w_b, w_b.ndim - 1)
    if spec.lut_bits <= 4:
        # int4-packable tables dequantize as f32(code) * f32(scale) — the
        # exact product the kernel's in-lane unpack computes — instead of
        # the f64-product-then-cast form (1-ulp divergence risk).
        lut_f32 = np.float32(entry["lut_q"]) * np.float32(entry["scale"])
    else:
        lut_f32 = np.asarray(entry["lut_q"] * entry["scale"], np.float32)
    return {
        "c_q": jnp.asarray(c_q),
        "c_scale": jnp.asarray(c_scale),
        "w_b_q": jnp.asarray(w_b_q),
        "w_b_scale": jnp.asarray(w_b_scale),
        "lut": jnp.asarray(lut_f32),
        "lut_q": jnp.asarray(entry["lut_q"], jnp.int32),
        "lut_scale": jnp.float32(entry["scale"]),
        "hemi": jnp.asarray(entry["hemi"], jnp.int32),
    }


def kan_layer_apply_quantized(qparams, x: jax.Array, spec: ASPQuantSpec) -> jax.Array:
    """ASP inference path: quantize -> shared-LUT dense basis -> banded matmul.

    Bit-exact contract with kernels/kan_spline's ref.py (the Pallas kernel is
    validated against this composition).
    """
    codes = quantize_input(x, spec)
    basis = dense_basis_from_codes(codes, qparams["lut"], spec)  # (..., F, G+K)
    c = qparams["c_q"].astype(jnp.float32) * qparams["c_scale"]
    y = _spline_matmul(basis, c)
    xq = jax.nn.relu(
        spec.lo + codes.astype(jnp.float32) * spec.code_step
    )
    wb = qparams["w_b_q"].astype(jnp.float32) * qparams["w_b_scale"]
    return y + xq @ wb


# ----------------------------------------------------------------------------
# Stacks
# ----------------------------------------------------------------------------


def init_kan_network(key, kspec: KANSpec):
    spec = kspec.layer_spec()
    keys = jax.random.split(key, len(kspec.dims) - 1)
    return [
        init_kan_layer(k, din, dout, spec)
        for k, din, dout in zip(keys, kspec.dims[:-1], kspec.dims[1:])
    ]


def kan_network_apply(params_list, x, kspec: KANSpec, quantized=False,
                      qparams_list=None, backend=None, interpret=None,
                      key=None):
    """Apply a KAN stack.

    The quantized path resolves its backend through ``repro.runtime``
    (explicit arg > ``use_backend`` scope > ``REPRO_KAN_BACKEND`` env var >
    "ref"):

      "ref":    layered jnp composition — quantize / SH-LUT / banded matmul /
                tanh-rescale per layer, activations round-trip through f32.
      "pallas": the fused multi-layer executor (kernels/kan_spline/pipeline):
                every layer runs in the Pallas kernel and inter-layer
                requantization is fused, activations stay int codes.
      "acim":   the fused executor with the paper's RRAM-ACIM non-idealities
                injected at the MAC stage (pass ``key`` to seed the noise).
    """
    if quantized:
        from .. import runtime
        from .kan_network_deploy import (
            deploy_kan_network,
            kan_network_deploy_apply,
        )

        name = runtime.resolve_backend(backend, default="ref")
        dep = deploy_kan_network(qparams_list, kspec, batch=x.shape[0])
        return kan_network_deploy_apply(
            dep, x, interpret=interpret, backend=name, key=key
        )
    if backend not in (None, "ref"):
        raise ValueError(
            f"backend={backend!r} is a quantized executor; "
            "pass quantized=True with qparams_list"
        )
    spec = kspec.layer_spec()
    h = x
    n = len(params_list)
    for li in range(n):
        h = kan_layer_apply(params_list[li], h, spec)
        if li < n - 1:
            # keep hidden activations inside the knot domain (KAN layers
            # calibrate their domain; tanh is the standard bounded choice)
            h = jnp.tanh(h) * (0.5 * (spec.hi - spec.lo)) + 0.5 * (spec.hi + spec.lo)
    return h


def param_count(kspec: KANSpec) -> int:
    """Edge count x (G + K + 1), matching the paper's #Param convention.

    (17,1,14) with G=5, K=3 -> 31 * 9 = 279 = the paper's KAN1;
    G=68 -> 31 * 72 = 2232 = the paper's KAN2.
    """
    edges = sum(a * b for a, b in zip(kspec.dims[:-1], kspec.dims[1:]))
    return edges * (kspec.grid_size + kspec.order + 1)


# ----------------------------------------------------------------------------
# Grid extension (original-KAN §2.5; used by KAN-NeuroSim step 2)
# ----------------------------------------------------------------------------


def refit_layer_spec(
    params, old_spec: ASPQuantSpec, new_spec: ASPQuantSpec
) -> dict:
    """Refit layer coefficients onto a different (G, K) basis by least squares.

    Samples the old spline densely, solves for new coefficients such that
    the new-spec spline matches — the standard grid-extension transfer,
    generalized to arbitrary target grid size AND order so the co-design
    search (``repro.tune``) can score candidate (G, K) points from one
    trained base network without retraining per candidate.  Refitting to a
    finer grid is near-lossless; to a coarser grid it is the best L2
    approximation — exactly the fidelity/cost trade-off being searched.
    w_b is unchanged.
    """
    new_g, new_k = new_spec.grid_size, new_spec.order
    xs = jnp.linspace(
        old_spec.lo, old_spec.hi, 4 * (new_g + new_k) + 16, dtype=jnp.float32
    )
    old_b = bspline_basis(xs, old_spec.lo, old_spec.hi, old_spec.grid_size, old_spec.order)
    new_b = bspline_basis(xs, new_spec.lo, new_spec.hi, new_g, new_k)
    c = params["c"]  # (F, nb_old, O)
    f, nb_old, o = c.shape
    targets = jnp.einsum("sn,fno->sfo", old_b, c).reshape(len(xs), f * o)
    sol, *_ = jnp.linalg.lstsq(new_b, targets)
    c_new = sol.reshape(new_g + new_k, f, o).transpose(1, 0, 2)
    return {"c": c_new, "w_b": params["w_b"]}


def extend_layer_grid(params, old_spec: ASPQuantSpec, new_g: int) -> dict:
    """Refit layer coefficients on a finer grid by least squares (same K)."""
    return refit_layer_spec(
        params, old_spec, dataclasses.replace(old_spec, grid_size=new_g)
    )
