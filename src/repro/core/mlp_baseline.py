"""Traditional-MLP baseline (paper Fig. 13, ref. [22] Davies et al.).

17-420-420-14 ReLU MLP: 17*420+420 + 420*420+420 + 420*14+14 = 190,274
parameters — the paper reports 190,214; the small delta is bias-counting.
Trained with the same recipe as the KANs so the accuracy comparison is fair.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..train.optimizer import adamw, apply_updates

__all__ = ["init_mlp", "mlp_apply", "train_mlp", "mlp_param_count", "PAPER_MLP_DIMS"]

PAPER_MLP_DIMS = (17, 420, 420, 14)


def mlp_param_count(dims=PAPER_MLP_DIMS) -> int:
    return sum(i * o + o for i, o in zip(dims[:-1], dims[1:]))


def init_mlp(key, dims=PAPER_MLP_DIMS, dtype=jnp.float32):
    params = []
    for i, o in zip(dims[:-1], dims[1:]):
        key, sk = jax.random.split(key)
        w = jax.random.normal(sk, (i, o), dtype) * jnp.sqrt(2.0 / i)
        params.append({"w": w, "b": jnp.zeros((o,), dtype)})
    return params


def mlp_apply(params, x):
    h = x
    for li, p in enumerate(params):
        h = h @ p["w"] + p["b"]
        if li < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def train_mlp(params, x_train, y_train, x_val, y_val, epochs=200,
              batch_size=2048, lr=3e-3, seed=0):
    key = jax.random.PRNGKey(seed)
    opt = adamw(lr, weight_decay=1e-4)
    opt_state = opt.init(params)
    x_train = jnp.asarray(x_train)
    y_train = jnp.asarray(y_train)
    n = x_train.shape[0]
    steps = max(1, n // batch_size)

    def loss_fn(params, xb, yb):
        logits = mlp_apply(params, xb)
        return -jnp.take_along_axis(
            jax.nn.log_softmax(logits), yb[:, None], axis=1
        ).mean()

    @jax.jit
    def step(params, opt_state, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    history = []
    for _ in range(epochs):
        key, sk = jax.random.split(key)
        perm = jax.random.permutation(sk, n)
        for s in range(steps):
            idx = perm[s * batch_size : (s + 1) * batch_size]
            params, opt_state, _ = step(params, opt_state, x_train[idx], y_train[idx])
        logits = mlp_apply(params, jnp.asarray(x_val))
        history.append(float((jnp.argmax(logits, -1) == jnp.asarray(y_val)).mean()))
    return params, history
