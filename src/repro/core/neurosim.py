"""KAN-NeuroSim: hyperparameter optimization framework (paper §3.4, Fig. 9).

Two steps:

  * **Step 1** — constraint loop: given hardware constraints (area, energy,
    latency) and KAN hyperparameters (dims, K, G, input method), evaluate the
    accelerator cost model (costmodel.py, our NeuroSim extension) and shrink
    G / switch TM-DV mode until the constraints hold.

  * **Step 2** — grid extension training: train for N epochs; if validation
    loss keeps decreasing AND the extended grid (G + E) still satisfies the
    constraints, extend the grid (kan_layer.extend_layer_grid) and continue;
    otherwise revert to the previous G and stop.

RRAM non-ideal effects (partial-sum error, IR-drop — statistics in cim.py
calibrated to the paper's TSMC 22nm measurements) are applied in the
evaluation path so the searched hyperparameters are ACIM-aware.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .asp_quant import ASPQuantSpec, resolve_layer_bits
from .cim import CIMConfig, cim_matmul
from .costmodel import accelerator_cost, kan_accelerator
from .kan_layer import (
    KANSpec,
    extend_layer_grid,
    init_kan_network,
    kan_network_apply,
    quantize_kan_layer,
)
from .sam import row_activation_weight, sam_permutation
from .tmdv import TMDVConfig
from ..train.optimizer import adamw, apply_updates

__all__ = [
    "HardwareConstraints",
    "check_constraints",
    "kan_cost",
    "search_max_grid",
    "train_kan",
    "evaluate_accuracy",
    "evaluate_accuracy_cim",
    "grid_extension_train",
]


@dataclasses.dataclass(frozen=True)
class HardwareConstraints:
    max_area_mm2: float = float("inf")
    max_energy_pj: float = float("inf")
    max_latency_ns: float = float("inf")


def kan_cost(dims, grid_size, order, n_bits, input_gen, array_rows=128,
             adc_bits=8, layer_bits=()) -> dict:
    """Accelerator cost of one KAN hyperparameter point (area/energy/latency).

    The single cost hook shared by the step-1 constraint loop here and the
    Pareto search in ``repro.tune.search``.  Raises ``ValueError`` when G
    does not fit the bit budget (eq. (6)) — for the uniform ``n_bits`` and
    for every width in a mixed-precision ``layer_bits`` allocation alike.
    ``layer_bits`` scales each layer's cell area/energy by its weight width
    (int4-packed layers cost half the 8-bit cell footprint).
    """
    spec = ASPQuantSpec(grid_size=grid_size, order=order, n_bits=n_bits,
                        lut_bits=n_bits, lo=-1.0, hi=1.0)
    if layer_bits:
        # per-layer PowerGap validation (raises ValueError, never clamps)
        resolve_layer_bits(layer_bits, len(dims) - 1, grid_size)
    acc = kan_accelerator(dims, spec, input_gen, array_rows, adc_bits,
                          layer_bits=tuple(layer_bits))
    return accelerator_cost(acc)


_cost_for = kan_cost  # internal alias kept for the step-2 loop below


def check_constraints(cost: dict, hc: HardwareConstraints) -> bool:
    return (
        cost["area_mm2"] <= hc.max_area_mm2
        and cost["energy_pj"] <= hc.max_energy_pj
        and cost["latency_ns"] <= hc.max_latency_ns
    )


def search_max_grid(
    dims,
    hc: HardwareConstraints,
    order: int = 3,
    n_bits: int = 8,
    input_gen: TMDVConfig | None = None,
    array_rows: int = 128,
    adc_bits: int = 8,
    g_candidates=None,
) -> tuple:
    """Step 1: largest G whose accelerator satisfies the constraints.

    Returns (best_G, cost dict) or (None, None) if even the smallest fails.
    """
    if input_gen is None:
        input_gen = TMDVConfig(total_bits=n_bits, voltage_bits=n_bits // 2)
    if g_candidates is None:
        g_candidates = [g for g in range(1, 2**n_bits) if ASPQuantSpec(g, order, n_bits).ld >= 0]
    best = (None, None)
    for g in sorted(g_candidates):
        try:
            cost = _cost_for(dims, g, order, n_bits, input_gen, array_rows, adc_bits)
        except ValueError:
            continue
        if check_constraints(cost, hc):
            best = (g, cost)
    return best


# ----------------------------------------------------------------------------
# Training / evaluation on a classification task (knot theory)
# ----------------------------------------------------------------------------


def _loss_fn(params, x, y, kspec):
    logits = kan_network_apply(params, x, kspec)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll


def train_kan(
    kspec: KANSpec,
    x_train,
    y_train,
    x_val,
    y_val,
    epochs: int = 200,
    batch_size: int = 1024,
    lr: float = 3e-3,
    seed: int = 0,
    params=None,
    verbose: bool = False,
):
    """Mini-batch AdamW training of a KAN stack; returns (params, history)."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = init_kan_network(key, kspec)
    opt = adamw(lr, weight_decay=1e-4)
    opt_state = opt.init(params)

    x_train = jnp.asarray(x_train)
    y_train = jnp.asarray(y_train)
    n = x_train.shape[0]
    steps = max(1, n // batch_size)

    @jax.jit
    def step(params, opt_state, xb, yb):
        loss, grads = jax.value_and_grad(_loss_fn)(params, xb, yb, kspec)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    @jax.jit
    def val_loss(params):
        return _loss_fn(params, jnp.asarray(x_val), jnp.asarray(y_val), kspec)

    history = []
    for ep in range(epochs):
        key, sk = jax.random.split(key)
        perm = jax.random.permutation(sk, n)
        for s in range(steps):
            idx = perm[s * batch_size : (s + 1) * batch_size]
            params, opt_state, loss = step(params, opt_state, x_train[idx], y_train[idx])
        history.append(float(val_loss(params)))
        if verbose and (ep % 25 == 0 or ep == epochs - 1):
            print(f"  epoch {ep}: val_loss {history[-1]:.4f}")
    return params, history


def evaluate_accuracy(params, x, y, kspec: KANSpec) -> float:
    logits = kan_network_apply(params, jnp.asarray(x), kspec)
    return float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean())


def evaluate_accuracy_cim(
    params,
    x,
    y,
    kspec: KANSpec,
    cim_cfg: CIMConfig,
    key,
    use_sam: bool = False,
    calib_x=None,
) -> float:
    """Accuracy with the quantized spline path executed on the ACIM simulator.

    The spline matmul of every layer runs through cim_matmul with the layer's
    c' int8 rows as conductances and the dense basis as WL drives; KAN-SAM
    optionally permutes the physical rows by activation probability.
    """
    from .asp_quant import dense_basis_from_codes, quantize_input

    spec = kspec.layer_spec()
    x = jnp.asarray(x)
    h = x
    n_layers = len(params)
    for li, p in enumerate(params):
        qp = quantize_kan_layer(p, spec)
        codes = quantize_input(h, spec)
        basis = dense_basis_from_codes(codes, qp["lut"], spec)  # (B, F, nb)
        bsz, f, nb = basis.shape
        # WL drives in code units (lut_bits full-scale)
        drives = basis.reshape(bsz, f * nb) / float(qp["lut_scale"])
        w_rows = (qp["c_q"].astype(jnp.float32)).reshape(f * nb, -1)
        perm = None
        if use_sam:
            cx = h if calib_x is None or li > 0 else jnp.asarray(calib_x)
            rw = row_activation_weight(cx if li == 0 else h, spec, f)
            perm = sam_permutation(rw, cim_cfg.array_rows)
        key, sk = jax.random.split(key)
        acc = cim_matmul(drives, w_rows, cim_cfg, sk, row_perm=perm,
                         x_max=float(2**spec.lut_bits - 1),
                         adc_calibrate=True)
        y_spline = acc * float(qp["lut_scale"]) * qp["c_scale"][None, :]
        xq = jax.nn.relu(spec.lo + codes.astype(jnp.float32) * spec.code_step)
        wb = qp["w_b_q"].astype(jnp.float32) * qp["w_b_scale"]
        h = y_spline + xq @ wb
        if li < n_layers - 1:
            h = jnp.tanh(h) * (0.5 * (spec.hi - spec.lo)) + 0.5 * (spec.hi + spec.lo)
    return float((jnp.argmax(h, -1) == jnp.asarray(y)).mean())


# ----------------------------------------------------------------------------
# Step 2: grid-extension training under constraints
# ----------------------------------------------------------------------------


def grid_extension_train(
    dims,
    hc: HardwareConstraints,
    x_train,
    y_train,
    x_val,
    y_val,
    g_init: int = 3,
    extend_by: int = 2,
    epochs_per_round: int = 60,
    max_rounds: int = 8,
    order: int = 3,
    n_bits: int = 8,
    input_gen: TMDVConfig | None = None,
    array_rows: int = 128,
    adc_bits: int = 8,
    seed: int = 0,
    verbose: bool = False,
):
    """Paper Fig. 9 step 2.  Returns dict with final params/G/cost/history."""
    if input_gen is None:
        input_gen = TMDVConfig(total_bits=n_bits, voltage_bits=n_bits // 2)

    g = g_init
    kspec = KANSpec(dims=tuple(dims), grid_size=g, order=order, n_bits=n_bits,
                    lut_bits=n_bits)
    params, hist = train_kan(kspec, x_train, y_train, x_val, y_val,
                             epochs=epochs_per_round, seed=seed, verbose=verbose)
    best_val = hist[-1]
    log = [{"G": g, "val_loss": best_val}]

    for _ in range(max_rounds):
        g_next = g + extend_by
        try:
            cost_next = _cost_for(dims, g_next, order, n_bits, input_gen,
                                  array_rows, adc_bits)
        except ValueError:
            break  # G*2^LD no longer fits in n bits
        if not check_constraints(cost_next, hc):
            break  # hardware budget exceeded -> keep G_pre
        params_pre, kspec_pre = params, kspec  # "1. G_pre = G"
        spec = kspec.layer_spec()
        params = [extend_layer_grid(p, spec, g_next) for p in params]
        kspec = dataclasses.replace(kspec, grid_size=g_next)
        params, hist = train_kan(kspec, x_train, y_train, x_val, y_val,
                                 epochs=epochs_per_round, seed=seed,
                                 params=params, verbose=verbose)
        log.append({"G": g_next, "val_loss": hist[-1]})
        if hist[-1] >= best_val:  # val loss stopped decreasing: "2. G = G_pre"
            params, kspec = params_pre, kspec_pre
            break
        best_val = hist[-1]
        g = g_next

    cost = _cost_for(dims, g, order, n_bits, input_gen, array_rows, adc_bits)
    return {
        "params": params,
        "kspec": kspec,
        "G": g,
        "cost": cost,
        "log": log,
    }
