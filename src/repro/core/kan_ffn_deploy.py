"""Deployment path for LM KAN-FFN layers: ASP-quantize + fused Pallas pipeline.

Closes the loop between the paper's edge-inference technique and the LM
substrate: a trained KAN-FFN block (models/layers.init_ffn with
ffn_kind="kan") is post-training-quantized with ASP-KAN-HAQ (int8 c', shared
SH-LUT) and executed through the kernels/kan_spline **fused pipeline** — both
KANLinear halves run in the Pallas kernel and the inter-half boundary
(tanh -> ASP re-coding) is fused into the first half's kernel, so the hidden
activation crosses the boundary as int codes (plus the raw f32 copy the
second half's ReLU branch contracts against).

    qffn = quantize_kan_ffn(ffn_params, cfg)
    y = kan_ffn_apply_quantized(qffn, x, cfg, interpret=True)   # == ffn(x)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .kan_layer import quantize_kan_layer
from .kan_network_deploy import deploy_kan_ffn_stack, kan_network_deploy_apply

__all__ = [
    "quantize_kan_ffn",
    "kan_ffn_apply_quantized",
    "quantize_kan_ffn_params_tree",
]


def quantize_kan_ffn(ffn_params: dict, cfg: ModelConfig) -> dict:
    """Quantize both KANLinear halves of a KAN-FFN block.

    ffn_params: {"c1","wb1","c2","wb2"} from models/layers.init_ffn.
    Returns {"l1": qparams, "l2": qparams} (see kan_layer.quantize_kan_layer)
    — the int8 + SH-LUT form is the ONLY stored copy (the paper's deployed
    residency; the old precomputed ``pipe_l1/l2`` duplicate doubled it).
    The runtime derives the padded f32 pipeline form on demand inside its
    cached executors.  Trade-off, made deliberately: when the qparams are
    jit *arguments* (the serving path) the dequantize+pad is O(weight size)
    elementwise work re-executed per forward — the standard weight-only-
    quantization deal (int8 at rest and on the HBM read, decode on the fly)
    — while eager/deploy-time callers get it constant-folded at trace.
    """
    from ..models.layers import kan_ffn_specs

    s1, s2 = kan_ffn_specs(cfg)
    l1 = quantize_kan_layer({"c": ffn_params["c1"], "w_b": ffn_params["wb1"]},
                            s1)
    l2 = quantize_kan_layer({"c": ffn_params["c2"], "w_b": ffn_params["wb2"]},
                            s2)
    return {"l1": l1, "l2": l2}


def kan_ffn_apply_quantized(qffn: dict, x: jax.Array, cfg: ModelConfig,
                            interpret: bool | None = None,
                            backend: str | None = None,
                            mesh=None,
                            key=None) -> jax.Array:
    """Quantized KAN-FFN forward via the runtime-resolved executor.

    x: (B, S, D).  Mirrors models/layers.ffn(kind="kan"): each half applies
    tanh domain squash -> ASP quantize -> SH-LUT banded matmul, with the ReLU
    residual branch contracting the RAW pre-squash input (matching the float
    path models/layers._kan_linear).  ``interpret=None`` auto-selects
    interpret mode off-TPU; ``backend=None`` resolves through
    ``repro.runtime`` (scope > ``REPRO_KAN_BACKEND`` > "pallas") and
    ``mesh=None`` likewise (``use_mesh`` scope — how the serving engine
    shards every FFN token batch on "data" and hidden channels on "model").
    """
    from ..models.layers import kan_ffn_specs

    specs = kan_ffn_specs(cfg)
    b, s, d = x.shape
    hidden = qffn["l1"]["c_q"].shape[-1]
    dep = deploy_kan_ffn_stack(
        [qffn["l1"], qffn["l2"]], (d, hidden, d), specs, batch=b * s
    )
    x2 = x.reshape(b * s, d).astype(jnp.float32)
    y = kan_network_deploy_apply(
        dep, x2, interpret=interpret, backend=backend, mesh=mesh, key=key
    )
    return y.reshape(b, s, d).astype(x.dtype)


def quantize_kan_ffn_params_tree(params: dict, cfg: ModelConfig) -> dict:
    """Swap every KAN-FFN block in a model param tree for its quantized form.

    Walks the decoder (and encoder, if present) groups of a
    models.model.init_params tree; each stacked ``l{i}_ffn`` float dict
    (leading dim = scan repeats) is replaced by the stacked
    ``{"l1","l2"}`` qparams dict, which models/layers.ffn dispatches to the
    fused Pallas pipeline.  Host-side, run once at deploy time.
    """
    def q_group(gp: dict) -> dict:
        out = dict(gp)
        for k, v in gp.items():
            if not k.endswith("_ffn"):
                continue
            repeats = v["c1"].shape[0]
            qs = [
                quantize_kan_ffn(jax.tree.map(lambda a: a[r], v), cfg)
                for r in range(repeats)
            ]
            out[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *qs)
        return out

    p = dict(params)
    for stack_key in ("decoder", "encoder"):
        if stack_key in p:
            p[stack_key] = [q_group(g) for g in p[stack_key]]
    return p
