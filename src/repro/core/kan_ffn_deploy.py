"""Deployment path for LM KAN-FFN layers: ASP-quantize + Pallas kernel.

Closes the loop between the paper's edge-inference technique and the LM
substrate: a trained KAN-FFN block (models/layers.init_ffn with
ffn_kind="kan") is post-training-quantized with ASP-KAN-HAQ (int8 c', shared
SH-LUT) and executed through the kernels/kan_spline Pallas kernel — the
exact datapath the paper accelerates, at transformer width.

    qffn = quantize_kan_ffn(ffn_params, cfg)
    y = kan_ffn_apply_quantized(qffn, x, cfg, interpret=True)   # == ffn(x)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .asp_quant import quantize_input
from .kan_layer import quantize_kan_layer

__all__ = ["quantize_kan_ffn", "kan_ffn_apply_quantized"]


def quantize_kan_ffn(ffn_params: dict, cfg: ModelConfig) -> dict:
    """Quantize both KANLinear halves of a KAN-FFN block.

    ffn_params: {"c1","wb1","c2","wb2"} from models/layers.init_ffn.
    Returns {"l1": qparams, "l2": qparams} (see kan_layer.quantize_kan_layer).
    """
    from ..models.layers import kan_ffn_spec

    spec = kan_ffn_spec(cfg)
    l1 = quantize_kan_layer({"c": ffn_params["c1"], "w_b": ffn_params["wb1"]},
                            spec)
    l2 = quantize_kan_layer({"c": ffn_params["c2"], "w_b": ffn_params["wb2"]},
                            spec)
    return {"l1": l1, "l2": l2}


def kan_ffn_apply_quantized(qffn: dict, x: jax.Array, cfg: ModelConfig,
                            interpret: bool = False) -> jax.Array:
    """Quantized KAN-FFN forward via the kan_spline Pallas kernel.

    x: (B, S, D).  Mirrors models/layers.ffn(kind="kan"): each half applies
    tanh domain squash -> ASP quantize -> SH-LUT banded matmul + ReLU branch.
    """
    from ..kernels.kan_spline.ops import kan_spline
    from ..models.layers import kan_ffn_spec

    spec = kan_ffn_spec(cfg)
    b, s, d = x.shape

    def half(q, h2d):
        # spline term through the kernel on the tanh-squashed domain; the
        # ReLU residual branch uses the RAW pre-squash input (matching the
        # float path models/layers._kan_linear), so it is added outside.
        codes = quantize_input(jnp.tanh(h2d.astype(jnp.float32)), spec)
        wc = q["c_q"].astype(jnp.float32) * q["c_scale"]
        zeros_wb = jnp.zeros((wc.shape[0], wc.shape[-1]), jnp.float32)
        y = kan_spline(codes, q["lut"], wc, zeros_wb, spec,
                       interpret=interpret)
        wb = q["w_b_q"].astype(jnp.float32) * q["w_b_scale"]
        return y + jax.nn.relu(h2d.astype(jnp.float32)) @ wb

    h = half(qffn["l1"], x.reshape(b * s, d))
    y = half(qffn["l2"], h)
    return y.reshape(b, s, d).astype(x.dtype)
