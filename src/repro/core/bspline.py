"""Uniform B-spline basis — the float reference for KAN layers.

The original KAN paper (Liu et al., arXiv:2404.19756) parameterizes each edge
with ``spline(x) = sum_i c_i B_i(x)`` where ``B_i`` are order-K B-splines on a
uniform ("knot") grid of G intervals over ``[lo, hi]``, extended by K intervals
on each side, giving G+K basis functions.

Because the knots are uniform, every ``B_i`` is a shifted copy of one canonical
cardinal bump ``b_K`` supported on ``[0, K+1]`` (in knot units).  That is the
property the paper's ASP-KAN-HAQ exploits (see ``asp_quant.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "extended_knots",
    "bspline_basis",
    "bspline_basis_fast",
    "cardinal_bump",
    "num_basis",
]


def num_basis(grid_size: int, order: int) -> int:
    """Number of B-spline basis functions: G + K."""
    return grid_size + order


def extended_knots(lo: float, hi: float, grid_size: int, order: int) -> np.ndarray:
    """Uniform knot vector extended by `order` intervals on each side.

    Returns G + 2K + 1 knots: t_j = lo + (j - K) * h,  h = (hi-lo)/G.
    """
    h = (hi - lo) / grid_size
    j = np.arange(grid_size + 2 * order + 1, dtype=np.float64)
    return lo + (j - order) * h


def bspline_basis(x: jax.Array, lo: float, hi: float, grid_size: int, order: int) -> jax.Array:
    """Evaluate all G+K uniform B-spline bases at ``x`` (Cox–de Boor).

    Args:
      x: any shape, float.  Values outside [lo, hi] are clamped (KAN layers
        calibrate [lo, hi] to the input range, matching pykan's grid update).
      lo/hi: knot-grid domain.
      grid_size: G (number of intervals).
      order: K (spline order; K=3 → cubic).

    Returns:
      basis with shape ``x.shape + (G+K,)``; rows sum to 1 on [lo, hi].
    """
    knots = jnp.asarray(extended_knots(lo, hi, grid_size, order), dtype=x.dtype)
    # Clamp into the open domain so degree-0 indicators behave at hi.
    h = (hi - lo) / grid_size
    eps = jnp.asarray(1e-6 * h, dtype=x.dtype)
    xc = jnp.clip(x, lo, hi - eps)[..., None]  # (..., 1)

    # Degree-0: indicator over each of the G+2K knot intervals.
    t = knots  # (G+2K+1,)
    b = jnp.where((xc >= t[:-1]) & (xc < t[1:]), 1.0, 0.0)  # (..., G+2K)

    for k in range(1, order + 1):
        # b currently holds degree-(k-1) bases over knots[:len] windows.
        t_i = t[: -(k + 1)]
        t_ik = t[k:-1]
        t_i1 = t[1:-k]
        t_ik1 = t[k + 1 :]
        left = (xc - t_i) / (t_ik - t_i) * b[..., :-1]
        right = (t_ik1 - xc) / (t_ik1 - t_i1) * b[..., 1:]
        b = left + right

    return b  # (..., G+K)


@functools.lru_cache(maxsize=64)
def _cardinal_bump_coeffs(order: int) -> np.ndarray:
    """Polynomial coefficients of the canonical cardinal B-spline b_K.

    b_K is supported on [0, K+1]; on segment s (t in [s, s+1)) it is a degree-K
    polynomial in u = t - s.  Returns array (K+1, K+1): [segment, power].
    Computed exactly with the Cox–de Boor recursion over polynomial coeffs.
    """
    # poly[s] = coeffs (low→high power of u) of degree-k bump on segment s.
    # degree 0: one segment, constant 1 on [0,1).
    polys = [np.array([[1.0]])]  # index k → (k+1 segments, k+1 coeffs)
    for k in range(1, order + 1):
        prev = polys[k - 1]  # (k, k)
        cur = np.zeros((k + 1, k + 1))
        # b_k(t) = t/k * b_{k-1}(t) + (k+1-t)/k * b_{k-1}(t-1)
        for s in range(k + 1):
            # term 1: (t/k) * prev on segment s (exists if s <= k-1)
            if s <= k - 1:
                p = prev[s]  # coeffs in u, t = s + u
                # (s+u)/k * p(u)
                cur[s, : k] += (s / k) * p
                cur[s, 1 : k + 1] += (1.0 / k) * p
            # term 2: ((k+1-t)/k) * prev evaluated at (t-1) on segment s-1 of prev
            if 1 <= s <= k:
                p = prev[s - 1]
                # (k+1-s-u)/k * p(u)
                cur[s, : k] += ((k + 1 - s) / k) * p
                cur[s, 1 : k + 1] += (-1.0 / k) * p
        polys.append(cur)
    return polys[order]


def bspline_basis_fast(x: jax.Array, lo: float, hi: float, grid_size: int,
                       order: int) -> jax.Array:
    """Uniform-knot basis via the shared cardinal-bump polynomial.

    The ASP observation (all B_i are shifts of ONE bump) applied to the float
    path: instead of the Cox-de Boor recursion (which materializes K
    intermediate (x.shape, G+2K) f32 tensors — the dominant HBM traffic of
    KAN-FFN training, §Perf cell 3), evaluate the K+1 active values as
    degree-K polynomials in the intra-interval offset and place them at band
    positions with iota compare/select.  Exactly equal to bspline_basis for
    uniform knots (validated in tests).
    """
    h = (hi - lo) / grid_size
    tau = jnp.clip((x.astype(jnp.float32) - lo) / h, 0.0, grid_size * (1 - 1e-7))
    g = jnp.floor(tau)
    u = tau - g
    g = g.astype(jnp.int32)

    coeffs = _cardinal_bump_coeffs(order)  # (K+1 segments, K+1 powers)
    nb = grid_size + order
    iota = jnp.arange(nb, dtype=jnp.int32)
    basis = jnp.zeros(x.shape + (nb,), jnp.float32)
    for d in range(order + 1):
        seg = order - d  # active slot d lives on bump segment K-d
        val = jnp.zeros_like(u)
        for p in reversed(range(order + 1)):  # Horner
            val = val * u + float(coeffs[seg, p])
        basis = basis + jnp.where(
            iota == (g + d)[..., None], val[..., None], 0.0
        )
    return basis


def cardinal_bump(t: np.ndarray, order: int) -> np.ndarray:
    """Evaluate the canonical cardinal B-spline b_K on [0, K+1] (numpy)."""
    t = np.asarray(t, dtype=np.float64)
    coeffs = _cardinal_bump_coeffs(order)
    seg = np.clip(np.floor(t).astype(np.int64), 0, order)
    u = t - seg
    out = np.zeros_like(t)
    for p in range(order + 1):
        out += coeffs[seg, p] * u**p
    out = np.where((t < 0) | (t > order + 1), 0.0, out)
    return out
