"""22nm-calibrated analytical area/energy/latency model (Figs. 10, 11, 13).

Structure comes from component counts (LUT bits, decoder lines, TG throws,
DAC cells, delay stages, RRAM cells, ADCs, WL buffers); the handful of unit
constants are calibrated so the three paper tables are reproduced.  Areas in
um^2, energy in pJ, power in uW (normalized), latency in ns.

Scaling laws implemented (the actual contribution being validated):

* Conventional B(X) path (PACT-style): every one of the G+K basis functions
  needs its OWN programmable LUT (2**n entries), 8-bit decoder, 2**n:1
  TG-MUX  ->  area grows ~ (G+K) * 2**n.
* ASP B(X) path: ONE hemi-folded shared LUT ((K+1)*2**LD/2 entries),
  split (n-LD)/LD-bit decoders, (K+1) L:1 MUXes + (K+1) 1:G DEMUXes
  ->  area grows ~ G (demux) + 2**(n-LD) (global decoder), with the LUT
  *shrinking* as G grows at fixed n.
* Input generators: pure-voltage DAC area/power ~ levels (power ~ 4**bits to
  hold noise margin), pure-PWM latency ~ 2**bits, TM-DV N:1 splits the bits.
* Accelerator totals: RRAM cells = #params; WL buffers per row; shared
  IG blocks and ADC banks fire per array phase; phases = sum over layers of
  row-tiles x col-tiles.
"""

from __future__ import annotations

import dataclasses
import math

from .asp_quant import ASPQuantSpec, max_ld
from .tmdv import TMDVConfig, wl_latency_units

# ----------------------------------------------------------------------------
# Unit constants (22 nm).  Calibrated once against the paper's tables; see
# benchmarks/fig*.py for the side-by-side numbers.
# ----------------------------------------------------------------------------

A_LUT_BIT = 0.6      # programmable LUT bit incl. periphery share (um^2)
A_DEC_LINE = 0.9     # decoder area per output line
A_TG = 0.5           # transmission gate (mux/demux throw)
A_DAC_CELL = 1.0     # current-steering DAC unit cell
A_DELAY_STAGE = 0.404
A_TCM = 11.6         # PM-TCM control block
A_IG_BUF = 20.0      # shared input-generator buffer/driver block
A_WL_BUF = 2.0       # per-word-line buffer
A_RRAM_CELL = 0.12   # 1T1R cell
A_ADC = 640.0        # 8-bit SAR ADC slice per BL; area doubles per extra bit
A_BX_FIXED = 1068.0  # B(X)->IG transmission block (regs, routing, FSM)
A_DIG_LAYER = 1500.0 # per-layer digital (accum, shift-add, ctrl)

P_DAC_UNIT = 0.003   # DAC static power ~ P_DAC_UNIT * 4**bits (noise margin)
P_DELAY_STAGE = 0.001
P_TCM = 0.421
P_IG_BUF = 0.45

E_LUT_BIT_READ = 0.004   # pJ per bit read
E_DEC_LINE = 0.0015      # pJ per decoder line switched
E_TG = 0.001             # pJ per TG toggled
E_LEAK_AREA = 0.00028    # pJ per um^2 per lookup window (leakage share)
E_BX_FIXED = 3.3         # transmission block per lookup
E_MAC_CELL = 0.01        # pJ per RRAM cell MAC
E_ADC = 2.0              # pJ per ADC conversion
E_IG_PWM_SHARED = 300.0  # shared PWM gen blocks per phase (8-bit)
E_IG_PWM_WL = 6.6        # per-WL PWM drive energy (8-bit full-scale)
E_IG_TMDV_SHARED = 30.0  # shared TM-DV blocks per phase
E_IG_TMDV_WL = 0.1       # per-WL TM-DV drive energy per 16-unit window
E_DIG_LAYER = 15.0       # per-layer digital

T_UNIT_PULSE = 3.0       # ns, unit WL pulse
T_ADC = 50.0             # ns, ADC conversion
T_DIG_LAYER = 185.0      # ns, per-layer digital pipeline (incl. B(X) path)
T_DEC_LINE = 0.0105      # ns per global-decoder output line (B(X) retrieval)

ARRAY_ROWS_DEFAULT = 128
ARRAY_COLS = 128


# ----------------------------------------------------------------------------
# Fig. 10 — B(X) lookup path, conventional vs ASP
# ----------------------------------------------------------------------------


def bx_path_conventional(spec: ASPQuantSpec) -> dict:
    """Per-input-feature B(X) path with misaligned (PACT) quantization."""
    nb = spec.num_basis
    n = spec.n_bits
    lut_bits = (2**n) * spec.lut_bits          # per B_i
    area = nb * (
        lut_bits * A_LUT_BIT + (2**n) * A_DEC_LINE + (2**n) * A_TG
    ) + A_BX_FIXED
    # per lookup: only the K+1 ACTIVE B_i fire (decoder+mux+row read each),
    # but leakage is paid on the whole instantiated area.
    active = spec.order + 1
    energy = (
        active
        * (
            spec.lut_bits * E_LUT_BIT_READ
            + (2**n) * E_DEC_LINE
            + (2**n) * E_TG
        )
        + E_LEAK_AREA * area
        + E_BX_FIXED
    )
    return {"area_um2": area, "energy_pj": energy}


def bx_path_asp(spec: ASPQuantSpec) -> dict:
    """Per-input-feature B(X) path with ASP-KAN-HAQ (SH-LUT + split decode)."""
    K = spec.order
    ld = spec.ld
    g = spec.grid_size
    n = spec.n_bits
    hemi_entries = (K + 1) * 2**ld // 2 + 1
    area = (
        hemi_entries * spec.lut_bits * A_LUT_BIT
        + (2 ** (n - ld)) * A_DEC_LINE      # global decoder
        + (2**ld) * A_DEC_LINE              # local decoder
        + (K + 1) * (2**ld) * A_TG          # L:1 muxes
        + (K + 1) * g * A_TG                # 1:G demuxes
        + A_BX_FIXED
    )
    # one hemi-row read yields all K+1 active values
    energy = (
        (K + 1) * spec.lut_bits * E_LUT_BIT_READ
        + (2 ** (n - ld)) * E_DEC_LINE
        + (2**ld) * E_DEC_LINE
        + ((K + 1) * (2**ld) + (K + 1) * g) * E_TG
        + E_LEAK_AREA * area
        + E_BX_FIXED
    )
    return {"area_um2": area, "energy_pj": energy}


def bx_retrieval_latency_ns(spec: ASPQuantSpec) -> float:
    """ASP B(X) retrieval pipeline latency (global decoder dominates)."""
    return T_DEC_LINE * (2 ** (spec.n_bits - spec.ld))


# ----------------------------------------------------------------------------
# Fig. 11 — WL input generators
# ----------------------------------------------------------------------------


def input_generator_cost(cfg: TMDVConfig) -> dict:
    """Area/power/latency/FOM of one WL input-generator slice.

    pure voltage: voltage_bits == total_bits; pure PWM: voltage_bits == 0.
    FOM = 1 / (area * power * latency), reported normalized by caller.
    """
    vb, tb = cfg.voltage_bits, cfg.time_bits
    area = A_IG_BUF
    power = P_IG_BUF
    if vb > 0:
        area += A_DAC_CELL * 2**vb
        power += P_DAC_UNIT * 4**vb
    if tb > 0:
        area += A_DELAY_STAGE * 2**tb
        power += P_DELAY_STAGE * 2**tb
    if vb > 0 and tb > 0:
        area += A_TCM
        power += P_TCM
    latency = wl_latency_units(cfg) * T_UNIT_PULSE
    fom = 1.0 / (area * power * latency)
    return {"area_um2": area, "power_uw": power, "latency_ns": latency, "fom": fom}


# ----------------------------------------------------------------------------
# Fig. 13 — whole-accelerator model
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerGeom:
    rows: int            # word lines (MLP: in_dim; KAN: in_dim*(G+K) + in_dim)
    cols: int            # bit lines (out_dim)
    cells: int           # programmed cells (= params of this layer)
    cell_bits: int = 8   # weight width stored per crosspoint; <8-bit layers
                         # pack narrower conductance stacks, so their cell
                         # area/energy footprint scales by cell_bits/8


@dataclasses.dataclass(frozen=True)
class AcceleratorSpec:
    layers: tuple                 # tuple[LayerGeom, ...]
    input_gen: TMDVConfig         # WL input method
    array_rows: int = ARRAY_ROWS_DEFAULT
    adc_bits: int = 8             # partial-sum ADC resolution
    bx_spec: ASPQuantSpec | None = None  # None -> MLP (no B(X) path)
    bx_features: int = 0          # input features needing a B(X) path slice


def _phases(spec: AcceleratorSpec) -> int:
    """Sequential array activations: row-tiles x col-tiles per layer."""
    total = 0
    for l in spec.layers:
        total += math.ceil(l.rows / spec.array_rows) * math.ceil(l.cols / ARRAY_COLS)
    return total


def accelerator_cost(spec: AcceleratorSpec) -> dict:
    nl = len(spec.layers)
    # physical rows are padded to whole arrays; columns are laid out as-is
    padded_rows = [
        math.ceil(l.rows / spec.array_rows) * spec.array_rows for l in spec.layers
    ]
    rows_total = sum(padded_rows)
    # bit-dependent cell footprint: a layer stored at cell_bits < 8 programs
    # proportionally fewer conductance levels per crosspoint (int4 packing
    # halves the at-rest cell demand), shrinking both the allocated array
    # area and the per-MAC cell energy.  cell_bits == 8 everywhere degrades
    # to the original integer counts exactly.
    cells_alloc = sum(pr * l.cols * (l.cell_bits / 8.0)
                      for pr, l in zip(padded_rows, spec.layers))
    cells_prog = sum(l.cells * (l.cell_bits / 8.0) for l in spec.layers)
    phases = _phases(spec)
    adc_area_unit = A_ADC * 2 ** (spec.adc_bits - 8)
    # per-WL drive energy scales with the WL activation window
    pwm_like = spec.input_gen.voltage_bits == 0
    wl_scale = wl_latency_units(spec.input_gen) / (256.0 if pwm_like else 16.0)

    # --- area
    area = cells_alloc * A_RRAM_CELL
    area += rows_total * A_WL_BUF
    ig = input_generator_cost(spec.input_gen)
    area += ig["area_um2"] * nl  # shared IG blocks, one slice per layer
    adc_count = sum(l.cols for l in spec.layers)  # pitch-matched SAR per BL
    area += adc_count * adc_area_unit
    area += nl * A_DIG_LAYER
    bx_lat = 0.0
    if spec.bx_spec is not None:
        bx = bx_path_asp(spec.bx_spec)
        area += bx["area_um2"]  # shared across features (time-multiplexed)
        bx_lat = bx_retrieval_latency_ns(spec.bx_spec)

    # --- latency (ADC conversion time scales with resolution)
    t_adc = T_ADC * spec.adc_bits / 8.0
    t_phase = wl_latency_units(spec.input_gen) * T_UNIT_PULSE + t_adc
    latency = phases * t_phase + nl * (T_DIG_LAYER + bx_lat)

    # --- energy
    e_sh = E_IG_PWM_SHARED if pwm_like else E_IG_TMDV_SHARED
    e_wl = (E_IG_PWM_WL if pwm_like else E_IG_TMDV_WL) * wl_scale
    active_rows = sum(l.rows for l in spec.layers)
    energy = phases * e_sh + active_rows * e_wl
    energy += cells_prog * E_MAC_CELL
    e_adc = E_ADC * 2 ** ((spec.adc_bits - 8) / 2)  # SAR energy ~ 2^(b/2)
    for l in spec.layers:
        energy += (
            min(l.cols, ARRAY_COLS)
            * e_adc
            * math.ceil(l.rows / spec.array_rows)
            * math.ceil(l.cols / ARRAY_COLS)
        )
    energy += nl * E_DIG_LAYER
    if spec.bx_spec is not None:
        energy += spec.bx_features * bx_path_asp(spec.bx_spec)["energy_pj"]

    return {
        "area_mm2": area / 1e6,
        "energy_pj": energy,
        "latency_ns": latency,
        "phases": phases,
    }


def mlp_accelerator(dims, input_gen: TMDVConfig) -> AcceleratorSpec:
    layers = tuple(
        LayerGeom(rows=i, cols=o, cells=i * o + o)
        for i, o in zip(dims[:-1], dims[1:])
    )
    return AcceleratorSpec(layers=layers, input_gen=input_gen)


def kan_accelerator(
    dims,
    spec: ASPQuantSpec,
    input_gen: TMDVConfig,
    array_rows: int = ARRAY_ROWS_DEFAULT,
    adc_bits: int = 8,
    layer_bits: tuple = (),
) -> AcceleratorSpec:
    """``layer_bits``: per-layer weight widths (mixed precision); ``()``
    costs every layer at the spec's uniform ``n_bits``."""
    nb = spec.num_basis
    bits = tuple(layer_bits) if layer_bits \
        else (spec.n_bits,) * (len(dims) - 1)
    if len(bits) != len(dims) - 1:
        raise ValueError(
            f"layer_bits {layer_bits} vs {len(dims) - 1} layers")
    layers = tuple(
        LayerGeom(rows=i * nb + i, cols=o, cells=i * nb * o + i * o,
                  cell_bits=min(8, int(b)))
        for i, o, b in zip(dims[:-1], dims[1:], bits)
    )
    return AcceleratorSpec(
        layers=layers,
        input_gen=input_gen,
        array_rows=array_rows,
        adc_bits=adc_bits,
        bx_spec=spec,
        bx_features=max(dims[:-1]),
    )
