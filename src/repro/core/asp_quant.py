"""ASP-KAN-HAQ: Alignment-Symmetry and PowerGap KAN hardware-aware quantization.

Paper §3.1.  Two constraints on the input quantization grid:

* **Alignment-Symmetry** (phase one, eq. (4)): the quantization grid is an
  integer multiple ``L`` of the knot grid, ``G * L <= 2**n``.  Zero offset
  between the grids means every basis function B_i(x) is the *same* function of
  the intra-interval offset, so ONE look-up table is shared by all G+K bases;
  the cardinal bump's mirror symmetry ``b_K(t) = b_K(K+1-t)`` then halves the
  shared LUT (the "Sharable-Hemi LUT", SH-LUT).

* **PowerGap** (phase two, eq. (5)): knot spacing is a power of two,
  ``L = 2**LD`` (eq. (6): ``G * 2**LD <= 2**n``), so a quantized code splits
  into bit fields::

      code = [ global bits : ceil(log2 G) ][ local bits : LD ]
      global = code >> LD      -> knot-interval index g  ("which B_i band")
      local  = code &  (2**LD - 1) -> intra-interval offset ("where in the bump")

  On the paper's silicon this replaces an 8-bit decoder + 2L:1 TG-MUX trees
  with split (n-LD)-bit / LD-bit decoders and L:1 MUXes.  On TPU (see
  ``kernels/kan_spline``) the same bit split removes per-element dynamic
  gathers: the dense basis row is the SH-LUT value placed at band position
  ``global``, built with iota-compare/select — VPU-friendly, MXU-ready.

All functions are pure and jit-safe unless noted.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .bspline import bspline_basis, cardinal_bump

__all__ = [
    "ASPQuantSpec",
    "max_ld",
    "resolve_layer_bits",
    "lut_scale",
    "quantize_input",
    "dequantize_input",
    "build_lut",
    "hemi_fold",
    "hemi_unfold",
    "lookup_active",
    "dense_basis_from_codes",
    "quantized_dense_basis",
    "pact_quantize",
    "pact_basis_tables",
    "pact_dense_basis",
]


def max_ld(grid_size: int, n_bits: int) -> int:
    """Largest LD with ``G * 2**LD <= 2**n`` (paper eq. (6)).  -1 if none."""
    ld = -1
    while grid_size * 2 ** (ld + 1) <= 2**n_bits:
        ld += 1
    return ld


def resolve_layer_bits(n_bits, n_layers: int, grid_size: int) -> tuple:
    """Normalize a scalar-or-sequence bit width into a per-layer tuple.

    The mixed-precision entry point: every quantization surface that accepts
    ``n_bits`` as either one int (uniform, the paper's deployment) or a
    per-layer sequence (KANtize-style mixed precision) funnels through here.
    Each layer's width must independently satisfy PowerGap (eq. (6)):
    ``G * 2**LD <= 2**b`` must have a solution, i.e. ``max_ld(G, b) >= 0`` —
    an invalid allocation raises ``ValueError``, it is NEVER clamped.
    """
    if isinstance(n_bits, (int, np.integer)):
        bits = (int(n_bits),) * n_layers
    else:
        bits = tuple(int(b) for b in n_bits)
        if len(bits) != n_layers:
            raise ValueError(
                f"{len(bits)} per-layer bit widths for {n_layers} layers"
            )
    for li, b in enumerate(bits):
        if not 2 <= b <= 16:
            raise ValueError(f"layer {li}: n_bits={b} outside [2, 16]")
        if max_ld(grid_size, b) < 0:
            raise ValueError(
                f"layer {li}: n_bits={b} is PowerGap-invalid for "
                f"G={grid_size} (G * 2**LD <= 2**n unsatisfiable, eq. (6))"
            )
    return bits


def lut_scale(spec: "ASPQuantSpec") -> float:
    """Dequantization scale of the SH-LUT int codes (``lut ~= lut_q * s``).

    Derivable from the spec alone — bump peak over the code ceiling — so the
    fused kernel can bake it as a trace-time f32 constant when unpacking
    int4-packed LUT lanes (bit-exact with the deployed f32 table, which is
    stored as ``f32(lut_q) * f32(scale)`` whenever ``lut_bits <= 4``).
    """
    K = spec.order
    qmax = 2**spec.lut_bits - 1
    vmax = cardinal_bump(np.array([(K + 1) / 2.0]), K)[0]
    return float(vmax / qmax)


@dataclasses.dataclass(frozen=True)
class ASPQuantSpec:
    """Static description of one ASP-quantized KAN layer input.

    Attributes:
      grid_size: G, number of knot intervals.
      order: K, B-spline order (K=3 -> cubic).
      n_bits: n, system input bit width (paper uses 8).
      lut_bits: precision of stored B(X) values (feeds TM-DV-IG; paper 8).
      lo/hi: float input domain mapped onto the knot grid.
      signed: if True the code range is centered (layers with negative
        inputs, paper §3.1); purely an affine-map choice, the bit split is
        applied to the shifted unsigned code either way.
    """

    grid_size: int
    order: int = 3
    n_bits: int = 8
    lut_bits: int = 8
    lo: float = -1.0
    hi: float = 1.0
    signed: bool = False

    def __post_init__(self):
        if self.grid_size < 1:
            raise ValueError("grid_size must be >= 1")
        if max_ld(self.grid_size, self.n_bits) < 0:
            raise ValueError(
                f"G={self.grid_size} does not fit in {self.n_bits} bits: "
                "G * 2**LD <= 2**n unsatisfiable (eq. (6))"
            )

    @property
    def ld(self) -> int:
        """LD: local bit width (log2 of codes per knot interval)."""
        return max_ld(self.grid_size, self.n_bits)

    @property
    def codes_per_interval(self) -> int:
        return 2**self.ld

    @property
    def num_codes(self) -> int:
        """Data range is [0, G * 2**LD - 1] (paper §3.1.B)."""
        return self.grid_size * self.codes_per_interval

    @property
    def num_basis(self) -> int:
        return self.grid_size + self.order

    @property
    def global_bits(self) -> int:
        return max(1, math.ceil(math.log2(self.grid_size)))

    @property
    def knot_step(self) -> float:
        return (self.hi - self.lo) / self.grid_size

    @property
    def code_step(self) -> float:
        return self.knot_step / self.codes_per_interval


# ----------------------------------------------------------------------------
# Input quantization (the ASP affine map)
# ----------------------------------------------------------------------------


def quantize_input(x: jax.Array, spec: ASPQuantSpec) -> jax.Array:
    """Map float x in [lo, hi] to int32 code in [0, G*2**LD - 1].

    Codes are LEFT-aligned on the knot grid: code q corresponds to
    x = lo + q * code_step, so code q's knot interval is exactly q >> LD.
    This zero offset between grids is the Alignment property (paper §3.1
    phase one, eq. (4): the quantization grid is an integer multiple of
    the knot grid).
    """
    scale = 1.0 / spec.code_step
    q = jnp.floor((x - spec.lo) * scale + 0.5).astype(jnp.int32)
    return jnp.clip(q, 0, spec.num_codes - 1)


def dequantize_input(codes: jax.Array, spec: ASPQuantSpec) -> jax.Array:
    """Inverse affine map of :func:`quantize_input` (code grid -> floats)."""
    return spec.lo + codes.astype(jnp.float32) * spec.code_step


# ----------------------------------------------------------------------------
# SH-LUT construction
# ----------------------------------------------------------------------------


def build_lut(spec: ASPQuantSpec) -> dict:
    """Build the shared LUT of active-basis values (host-side, numpy).

    The payoff of Alignment-Symmetry (paper §3.1, Fig. 3): ONE table of
    (2**LD, K+1) bump values serves every basis function of every input
    feature, and its mirror symmetry halves the physical storage ("hemi").

    Returns dict with:
      "lut":      (2**LD, K+1) float64, lut[u, d] = value of the d-th active
                  basis B_{g+d} at local offset u  (= b_K(u/2**LD + K - d)).
      "lut_q":    same, quantized to ``lut_bits`` unsigned ints.
      "scale":    dequantization scale (lut ~= lut_q * scale).
      "hemi":     1-D hemi storage, ceil(((K+1)*2**LD)/2)+1 entries —
                  the physical SH-LUT (50% of the full table, paper Fig. 3).
      "flat_q":   full flattened (K+1)*2**LD int table reconstructed from
                  hemi (for checking hemi_unfold round-trips).
    """
    K, U = spec.order, spec.codes_per_interval
    u = np.arange(U, dtype=np.float64) / U
    # active slot d covers bump segment s = K - d  (see kernels/kan_spline).
    lut = np.stack([cardinal_bump(u + (K - d), K) for d in range(K + 1)], axis=1)
    scale = lut_scale(spec)  # bump peak / (2**lut_bits - 1)
    lut_q = np.round(lut / scale).astype(np.int64)
    hemi = hemi_fold(lut_q, spec)
    flat_q = hemi_unfold(hemi, spec)
    return {
        "lut": lut,
        "lut_q": lut_q,
        "scale": scale,
        "hemi": hemi,
        "flat_q": flat_q,
    }


def _flat_index_arrays(spec: ASPQuantSpec):
    """Flat bump-argument index f = s * 2**LD + local over the full table."""
    K, U = spec.order, spec.codes_per_interval
    total = (K + 1) * U
    f = np.arange(total)
    return f, total


def hemi_fold(lut_q: np.ndarray, spec: ASPQuantSpec) -> np.ndarray:
    """Fold the full (2**LD, K+1) table into hemi storage using symmetry.

    The Sharable-Hemi LUT (paper §3.1, Fig. 3): the cardinal bump's mirror
    symmetry b_K(t) = b_K(K+1-t) means the table's second half duplicates
    its first, so silicon stores 50% + 1 entries and reflects on retrieval.
    Flat bump position f = s*2**LD + u  (t = f / 2**LD in [0, K+1)) satisfies
    b(t) = b(K+1 - t), i.e. value at f equals value at total - f.  Physical
    storage keeps f in [0, total//2]; larger f are reflected on retrieval.
    """
    K, U = spec.order, spec.codes_per_interval
    f, total = _flat_index_arrays(spec)
    # reorganize (U, K+1)[u, d] -> flat[s*U + u] with s = K - d
    flat = np.zeros(total, dtype=lut_q.dtype)
    for d in range(K + 1):
        s = K - d
        flat[s * U : (s + 1) * U] = lut_q[:, d]
    half = total // 2
    return flat[: half + 1].copy()


def hemi_unfold(hemi: np.ndarray, spec: ASPQuantSpec) -> np.ndarray:
    """Reconstruct the full flat table from hemi storage (retrieval logic)."""
    f, total = _flat_index_arrays(spec)
    half = total // 2
    reflect = np.where(f <= half, f, total - f)
    return hemi[reflect]


# ----------------------------------------------------------------------------
# Quantized basis evaluation (the reference retrieval path)
# ----------------------------------------------------------------------------


def lookup_active(codes: jax.Array, lut: jax.Array, spec: ASPQuantSpec):
    """Active-basis retrieval: code -> (global g, (..., K+1) active values).

    ``lut`` is the (2**LD, K+1) table (float or dequantized).  This is the
    PowerGap bit split (paper §3.1 phase two, eq. (5)): shift/mask replaces
    the paper's split (n-LD)-bit / LD-bit decoders.
    """
    g = jax.lax.shift_right_logical(codes, spec.ld)
    local = jax.lax.bitwise_and(codes, spec.codes_per_interval - 1)
    vals = jnp.take(lut, local, axis=0)  # (..., K+1)
    return g, vals


def dense_basis_from_codes(
    codes: jax.Array, lut: jax.Array, spec: ASPQuantSpec
) -> jax.Array:
    """Dense (..., G+K) basis matrix built from the shared LUT.

    Implements the TPU-native ASP retrieval: place the K+1 active LUT values
    at band positions g..g+K via iota-compare/select (no dynamic gather on
    the output side).  This is the oracle for kernels/kan_spline.
    """
    g, vals = lookup_active(codes, lut, spec)
    nb = spec.num_basis
    iota = jnp.arange(nb, dtype=jnp.int32)  # basis index i
    # d = i - g in [0, K] selects active slot d.
    d = iota - g[..., None]
    active = (d >= 0) & (d <= spec.order)
    dd = jnp.clip(d, 0, spec.order)
    out = jnp.where(active, jnp.take_along_axis(
        jnp.broadcast_to(vals, g.shape + (spec.order + 1,)), dd * active, axis=-1
    ), 0.0)
    return out.astype(lut.dtype)


def quantized_dense_basis(x: jax.Array, spec: ASPQuantSpec, lut_entry: dict | None = None):
    """float x -> quantize -> dense dequantized basis (..., G+K)."""
    if lut_entry is None:
        lut_entry = build_lut(spec)
    lut = jnp.asarray(lut_entry["lut_q"] * lut_entry["scale"], dtype=jnp.float32)
    codes = quantize_input(x, spec)
    return dense_basis_from_codes(codes, lut, spec)


# ----------------------------------------------------------------------------
# Conventional (PACT-style) baseline — misaligned grids
# ----------------------------------------------------------------------------


def pact_quantize(x: jax.Array, alpha: float, n_bits: int) -> jax.Array:
    """PACT quantization (Choi et al. 2018): clip to [0, alpha], uniform n-bit.

    The quantization step alpha/(2**n - 1) is in general NOT an integer
    multiple of the knot step, so the knot and quantization grids are
    misaligned — each B_i(x) then needs its own code->value table.
    """
    q = jnp.round(jnp.clip(x, 0.0, alpha) / alpha * (2**n_bits - 1))
    return q.astype(jnp.int32)


def pact_basis_tables(
    spec: ASPQuantSpec, alpha: float | None = None
) -> np.ndarray:
    """Per-basis LUTs for the conventional path: (G+K, 2**n) table.

    table[i, q] = B_i(x(q)) with x(q) = q * alpha / (2**n - 1) + lo.
    Distinct per i because of grid misalignment (paper Fig. 2) — this is what
    costs G+K programmable LUTs + 8-bit decoders + 2L:1 MUX trees on silicon,
    and per-element dynamic gathers on TPU.
    """
    if alpha is None:
        alpha = spec.hi - spec.lo
    n = spec.n_bits
    q = np.arange(2**n, dtype=np.float64)
    x = spec.lo + q * alpha / (2**n - 1)
    tau = (x - spec.lo) / spec.knot_step  # [0, G]
    tables = np.stack(
        [cardinal_bump(tau - i + spec.order, spec.order) for i in range(spec.num_basis)],
        axis=0,
    )
    qmax = 2**spec.lut_bits - 1
    vmax = cardinal_bump(np.array([(spec.order + 1) / 2.0]), spec.order)[0]
    return np.round(tables / (vmax / qmax)) * (vmax / qmax)


def pact_dense_basis(x: jax.Array, spec: ASPQuantSpec, tables: np.ndarray) -> jax.Array:
    """Baseline dense basis via per-B_i tables (gather per basis function)."""
    alpha = spec.hi - spec.lo
    codes = pact_quantize(x - spec.lo, alpha, spec.n_bits)
    t = jnp.asarray(tables, dtype=jnp.float32)  # (G+K, 2**n)
    return jnp.take(t, codes, axis=1).transpose(
        tuple(range(1, codes.ndim + 1)) + (0,)
    )
