"""TM-DV-IG: N:1 Time-Modulation Dynamic-Voltage input generator (paper §3.2).

Behavioral model of the mixed time/voltage word-line DAC.  A ``2N``-bit input
code (a B(X) value from the SH-LUT) is split::

    code = hi * 2**N + lo
    hi (N bits) -> voltage level  V[hi]   (DAC configured so I[x] = x * I_u)
    lo (N bits) -> pulse width    lo * W_p1

and the charge integrated on the BL cap is::

    Q = I[hi] * W_pN + I[1] * (lo * W_p1)     with W_pN = 2**N * W_p1
      = (hi * 2**N + lo) * I_u * W_p1         (linear in the code)

Noise model (all per-WL-event, Gaussian):
  * voltage-domain: relative current-level noise sigma_v — scales with how
    finely the VDD range is subdivided (more DAC levels -> smaller margin).
  * time-domain: pulse-edge jitter sigma_t (in unit-pulse units) on each of
    the two pulse events.

The three input methods compared in the paper (Fig. 11) fall out of the same
model:
  * pure voltage : all 2N bits in voltage  -> 2**(2N) levels, 1 pulse slot.
  * pure PWM     : all 2N bits in time     -> 1 level, up to 2**(2N) slots.
  * TM-DV (N:1)  : N bits each             -> 2**N levels, 2**N slots.

TD-P / TD-A modes move the split point: TD-P puts more bits in voltage
(faster, noisier), TD-A more bits in time (slower, cleaner).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["TMDVConfig", "TD_A", "TD_P", "PURE_VOLTAGE", "PURE_PWM", "apply_input_noise", "wl_latency_units"]


@dataclasses.dataclass(frozen=True)
class TMDVConfig:
    """One TM-DV-IG operating point (paper §3.2).

    ``total_bits`` = 2N in the paper; ``voltage_bits`` = the bits carried
    by the DAC voltage level (the rest ride in the pulse width).  The
    paper's N:1 design point is the even split; sliding it reproduces the
    TD-P / TD-A modes and the pure-voltage / pure-PWM baselines of
    Fig. 11.
    """

    total_bits: int = 8
    voltage_bits: int = 4
    # Relative sigma of one DAC current level at 16 levels (4-bit) reference.
    sigma_v_ref: float = 0.015
    # Pulse-edge jitter in unit-pulse units.
    sigma_t: float = 0.08

    @property
    def time_bits(self) -> int:
        return self.total_bits - self.voltage_bits

    @property
    def num_levels(self) -> int:
        return 2**self.voltage_bits

    @property
    def sigma_v(self) -> float:
        # Noise margin shrinks linearly with the number of levels packed into
        # the fixed VDD range; 16 levels is the reference point.
        return self.sigma_v_ref * (self.num_levels / 16.0)


def TD_A(total_bits: int = 8) -> TMDVConfig:
    """High-accuracy mode (paper §3.2): fewer voltage levels
    (N_v = total/2 - 1) — wider noise margins, more pulse slots."""
    return TMDVConfig(total_bits=total_bits, voltage_bits=max(1, total_bits // 2 - 1))


def TD_P(total_bits: int = 8) -> TMDVConfig:
    """High-performance mode (paper §3.2): more voltage levels
    (N_v = total/2 + 1) — fewer pulse slots (faster WL), tighter margins."""
    return TMDVConfig(total_bits=total_bits, voltage_bits=min(total_bits - 1, total_bits // 2 + 1))


def PURE_VOLTAGE(total_bits: int = 8) -> TMDVConfig:
    return TMDVConfig(total_bits=total_bits, voltage_bits=total_bits)


def PURE_PWM(total_bits: int = 8) -> TMDVConfig:
    return TMDVConfig(total_bits=total_bits, voltage_bits=0)


def wl_latency_units(cfg: TMDVConfig) -> int:
    """WL activation window in unit pulses: the time field must fit.

    The latency half of the §3.2 trade (and the latency axis of the
    Fig. 11 comparison): moving a bit from time to voltage halves the
    window, at the cost of doubling the DAC level count (sigma_v grows).
    """
    return max(1, 2**cfg.time_bits)


def apply_input_noise(codes: jax.Array, cfg: TMDVConfig, key) -> jax.Array:
    """codes (int, in [0, 2**total_bits - 1]) -> noisy effective charge.

    Returns float "effective code" = Q / (I_u * W_p1); ideal value == codes.
    This is the input-generator error term of the paper's non-ideality
    evaluation (Fig. 11 compares the three input methods; the acim runtime
    backend and ``core.cim.cim_matmul`` inject it ahead of the MAC).
    """
    codes = codes.astype(jnp.float32)
    tmask = float(2**cfg.time_bits - 1) if cfg.time_bits > 0 else 0.0
    hi = jnp.floor(codes / max(1, 2**cfg.time_bits))
    lo = codes - hi * max(1, 2**cfg.time_bits)
    k1, k2, k3 = jax.random.split(key, 3)
    # voltage-part charge: hi * 2**time_bits, with relative level noise
    v_noise = 1.0 + cfg.sigma_v * jax.random.normal(k1, codes.shape)
    q_v = hi * max(1, 2**cfg.time_bits) * v_noise
    # time-part charge: lo (at unit current), edge jitter on both events
    t_noise = cfg.sigma_t * jax.random.normal(k2, codes.shape)
    q_t = jnp.where(lo > 0, lo + t_noise, 0.0)
    # pure-PWM carries everything in lo; pure-voltage everything in hi
    del tmask, k3
    return q_v + q_t
