"""KAN-SAM: sparsity-aware weight mapping (paper §3.3).

Only K+1 of the G+K basis functions fire for any input, so the word-line rows
of the c' array have very unequal activation probability.  IR-drop
attenuation on a BL grows with a row's distance from the clamping circuit,
and deployment (cim.py) compensates each column digitally by the MEAN
attenuation over the array.  The placement-dependent residual is therefore
minimized by mapping the highest-drive rows to positions whose distance is
CLOSEST TO THE COMPENSATED MEAN — their attenuation then matches the digital
correction almost exactly, while rarely-firing rows absorb the extreme
near/far slots where the mismatch is largest.  (Without mean compensation
this reduces to the paper's nearest-the-clamp mapping: both orderings put
the bulk of the expected current where its IR-drop exposure is cancelled.)
A pure permutation, no hardware or algorithm change.

Physical convention used throughout ``cim.py``: physical row 0 is closest to
the BL clamp (lowest IR-drop); attenuation grows with physical row index.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .asp_quant import ASPQuantSpec, quantize_input

__all__ = [
    "basis_activation_probability",
    "row_activation_weight",
    "sam_permutation",
    "identity_permutation",
    "apply_row_permutation",
]


def basis_activation_probability(x_samples: jax.Array, spec: ASPQuantSpec) -> jax.Array:
    """P_i = fraction of inputs for which B_i is active (g <= i <= g+K).

    The sparsity KAN-SAM exploits (paper §3.3): B-spline locality means
    only the K+1 bases of the input's knot interval fire, so for G >> K
    most word-line rows are idle most of the time — and unequally so.
    x_samples: (..., ) calibration inputs for ONE input feature (or pooled).
    Returns (G+K,) probabilities.
    """
    codes = quantize_input(x_samples.reshape(-1), spec)
    g = codes >> spec.ld  # active bands are g..g+K
    nb = spec.num_basis
    iota = jnp.arange(nb)
    active = (iota[None, :] >= g[:, None]) & (iota[None, :] <= g[:, None] + spec.order)
    return active.mean(axis=0)


def row_activation_weight(
    x_samples: jax.Array, spec: ASPQuantSpec, in_dim: int
) -> jax.Array:
    """Expected |current| weight per word-line row of a KAN layer.

    Rows are the flattened (feature f, basis i) pairs, row = f * (G+K) + i.
    x_samples: (S, in_dim) calibration batch.  The weight is
    P(B_i active for x_f) * E[B_i(x_f) | active] ~ E[B_i(x_f)] — mean WL
    drive, which is what loads the BL.
    """
    from .bspline import bspline_basis

    b = bspline_basis(x_samples, spec.lo, spec.hi, spec.grid_size, spec.order)
    mean_drive = b.mean(axis=0)  # (in_dim, G+K)
    return mean_drive.reshape(in_dim * spec.num_basis)


def sam_permutation(row_weight: jax.Array, array_rows: int | None = None) -> np.ndarray:
    """perm[p] = logical row placed at physical (flat) position p.

    The KAN sparsity-aware mapping strategy itself (paper §3.3) adapted to
    mean-compensated columns — see the module docstring for why the target
    distance is the compensated mean rather than the clamp.

    Physical distance from the BL clamp of flat position p is
    ((p % array_rows) + 1) / array_rows; deployment compensates each column
    by the attenuation at the array's MEAN distance (cim.py).  The highest
    expected-drive logical rows go to the slots whose distance is closest to
    that compensated mean (interleaved across array tiles), so their
    attenuation is cancelled by the digital correction; the rarely-active
    rows take the extreme near/far slots.
    """
    w = np.asarray(row_weight)
    r = len(w)
    best_first = np.argsort(-w, kind="stable")
    rows = r if array_rows is None else array_rows
    dist = ((np.arange(r) % rows) + 1.0) / rows
    mean_d = (rows + 1.0) / (2.0 * rows)
    pos_by_match = np.argsort(np.abs(dist - mean_d), kind="stable")
    perm = np.empty(r, np.int64)
    perm[pos_by_match] = best_first
    return perm


def identity_permutation(n_rows: int) -> np.ndarray:
    return np.arange(n_rows)


def apply_row_permutation(w_rows: jax.Array, perm) -> jax.Array:
    """Place logical rows at their physical positions: out[p] = w[perm[p]]."""
    return jnp.take(w_rows, jnp.asarray(perm), axis=0)
