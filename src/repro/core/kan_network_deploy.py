"""Deployment of whole quantized KAN networks: quantize + bind for the runtime.

This module is the thin host-side layer between trained/quantized KAN params
and :mod:`repro.runtime`: it post-training-quantizes a stack, dequantizes and
zero-pads the weights to the batch-independent pipeline geometry, and hands
the resulting :class:`DeployedKAN` bundle to the runtime's executor registry.
All *execution* concerns — backend selection (``ref`` / ``pallas`` /
``acim``), batch bucketing, plan/compile caching, non-ideality injection —
live in the runtime, not here.

    qparams_list = quantize_kan_network(params_list, kspec)
    dep = deploy_kan_network(qparams_list, kspec, batch=B)
    y = kan_network_deploy_apply(dep, x)                 # resolved backend
    y = kan_network_deploy_apply(dep, x, backend="acim", key=key)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .asp_quant import ASPQuantSpec
from .kan_layer import KANSpec, quantize_kan_layer
from .. import runtime
from ..kernels.kan_spline.pipeline import (
    PipelinePlan,
    pack_layer_weights,
    pack_lut,
    packs_lut,
    packs_weights,
    pad_layer_weights,
)
from ..runtime.executor import default_interpret  # re-export (PR-1 API)

__all__ = [
    "DeployedKAN",
    "quantize_kan_network",
    "deploy_kan_network",
    "deploy_kan_ffn_stack",
    "place_deployed_kan",
    "kan_network_deploy_apply",
    "kan_network_apply_ref",
    "default_interpret",
]


@dataclasses.dataclass
class DeployedKAN:
    """A quantized KAN stack bound to a pipeline geometry plan.

    layers: tuple of per-layer weight dicts, already padded to the plan:
    {"lut", "wc", "wb"} dequantized f32 for 8-bit layers, or the int4-packed
    {"lut"[, "lutp"], "wcp", "wscale", "wb"} form for <=4-bit layers (two
    signed weight codes per int8 lane; the kernel decodes in-lane).
    specs/dims describe the logical network for the runtime backends.
    placement: the mesh this bundle's weights were placed on with
    :func:`place_deployed_kan` (or None).  The runtime resolves it as the
    lowest-precedence mesh source (explicit ``mesh=`` arg > ``use_mesh``
    scope > this), and ``replan``/``dataclasses.replace`` carry it along, so
    a placed bundle keeps executing sharded across batch re-binds.
    """

    plan: PipelinePlan
    layers: tuple
    specs: tuple
    dims: tuple
    residual_raw: bool = False
    placement: object = None

    def replan(self, batch: int) -> "DeployedKAN":
        """Rebind to a new batch size — a plan-cache lookup, not a rebuild
        (weights/padding are batch-agnostic; the runtime buckets batches on
        its own, so this only matters for geometry introspection).  The
        placement, if any, survives the re-bind."""
        if batch == self.plan.b:
            return self
        plan = runtime.PLAN_CACHE.plan(
            batch, self.dims, self.specs, residual_raw=self.residual_raw
        )
        return dataclasses.replace(self, plan=plan)


def place_deployed_kan(dep: DeployedKAN, mesh) -> DeployedKAN:
    """Shard a deployed bundle's weights onto a mesh and record the placement.

    Weights are device_put with ``dist.sharding.deployed_kan_pspecs``
    (output channels on "model", SH-LUT replicated) — the exact layout the
    runtime's shard_map consumes, so sharded execution starts from resident
    shards with no re-layout.  The returned bundle carries ``placement=
    mesh``, which the runtime picks up as its default mesh; pass
    ``placement=None`` via ``dataclasses.replace`` to detach.
    """
    import jax as _jax

    from ..dist.sharding import deployed_kan_pspecs, to_shardings

    shardings = to_shardings(deployed_kan_pspecs(dep, mesh), mesh)
    layers = tuple(
        {k: _jax.device_put(a, s[k]) for k, a in lw.items()}
        for lw, s in zip(dep.layers, shardings)
    )
    return dataclasses.replace(dep, layers=layers, placement=mesh)


def quantize_kan_network(params_list, kspec: KANSpec):
    """Post-training-quantize every layer of a KAN stack (host-side).

    Mixed precision rides on the kspec: a per-layer ``n_bits`` tuple gives
    every layer its own spec (input width, clipped lut_bits, and the
    matching signed weight-code width via ``quantize_kan_layer``)."""
    specs = kspec.layer_specs()
    return [
        quantize_kan_layer(p, spec)
        for p, spec in zip(params_list, specs)
    ]


def _dequant_layer(qp: dict) -> tuple:
    wc = qp["c_q"].astype(jnp.float32) * qp["c_scale"]
    wb = qp["w_b_q"].astype(jnp.float32) * qp["w_b_scale"]
    return wc, wb


def deploy_kan_network(
    qparams_list, kspec: KANSpec, *, batch: int = 8
) -> DeployedKAN:
    """Bind a quantized KAN stack to a pipeline plan (per-layer specs)."""
    specs = kspec.layer_specs()
    dims = tuple(kspec.dims)
    return _deploy(qparams_list, dims, specs, batch, residual_raw=False)


def deploy_kan_ffn_stack(
    qparams_list, dims: tuple, spec, *, batch: int = 8
) -> DeployedKAN:
    """Bind a KANLinear chain with the raw-input ReLU branch (FFN contract).

    ``spec``: one ASPQuantSpec (broadcast to every layer) or a per-layer
    sequence of specs (mixed precision)."""
    if isinstance(spec, ASPQuantSpec):
        specs = tuple(spec for _ in qparams_list)
    else:
        specs = tuple(spec)
    return _deploy(qparams_list, tuple(dims), specs, batch, residual_raw=True)


def _deploy(qparams_list, dims, specs, batch, *, residual_raw) -> DeployedKAN:
    if len(dims) != len(qparams_list) + 1:
        raise ValueError(f"dims {dims} vs {len(qparams_list)} layers")
    plan = runtime.PLAN_CACHE.plan(batch, dims, specs,
                                   residual_raw=residual_raw)
    layers = []
    for qp, lp in zip(qparams_list, plan.layers):
        if qp["c_q"].shape != (lp.f, lp.spec.num_basis, lp.o):
            raise ValueError(
                f"layer weights {qp['c_q'].shape} != plan {lp}")
        wb = qp["w_b_q"].astype(jnp.float32) * qp["w_b_scale"]
        if packs_weights(lp.spec):
            # <=4-bit layer: keep the weight CODES, two per int8 lane —
            # the f32 banded matrix never materializes at rest
            layer = {
                "lut": qp["lut"],
                **pack_layer_weights(qp["c_q"], qp["c_scale"], wb, lp),
            }
            if packs_lut(lp.spec):
                layer["lutp"] = pack_lut(qp["lut_q"], lp.spec)
        else:
            wc, _ = _dequant_layer(qp)
            layer = {"lut": qp["lut"], **pad_layer_weights(wc, wb, lp)}
        layers.append(layer)
    return DeployedKAN(
        plan=plan, layers=tuple(layers), specs=specs, dims=dims,
        residual_raw=residual_raw,
    )


def kan_network_deploy_apply(
    dep: DeployedKAN,
    x: jax.Array,
    *,
    xraw: jax.Array | None = None,
    interpret: bool | None = None,
    backend: str | None = None,
    mesh=None,
    key=None,
    cim=None,
    sam_perms=None,
    return_intermediates: bool = False,
):
    """Run float input x (B, F0) through the runtime-resolved backend.

    ``backend=None`` resolves via the runtime (scope > ``REPRO_KAN_BACKEND``
    env var > "pallas"); ``mesh=None`` likewise (``use_mesh`` scope >
    ``dep.placement`` > unsharded).  ``key``/``cim``/``sam_perms`` only
    matter for the acim backend (``sam_perms``: per-layer KAN-SAM row
    placements).
    """
    return runtime.execute(
        dep, x, backend=backend, default="pallas",
        xraw=xraw, interpret=interpret, mesh=mesh, key=key, cim=cim,
        sam_perms=sam_perms,
        return_intermediates=return_intermediates,
    )


def kan_network_apply_ref(qparams_list, x: jax.Array, kspec: KANSpec):
    """The layered jnp reference the pipeline is bit-exact against
    (runtime ``ref`` composition over the un-padded quantized weights)."""
    from ..core.asp_quant import quantize_input

    specs = kspec.layer_specs()
    logical = []
    for qp in qparams_list:
        wc, wb = _dequant_layer(qp)
        logical.append((qp["lut"], wc, wb))
    codes = quantize_input(x, specs[0])
    return runtime.ref_composition(
        logical, specs, codes, None,
        residual_raw=False,
    )
