"""Deployment of whole quantized KAN networks onto the fused Pallas pipeline.

``kan_layer.kan_network_apply(..., quantized=True)`` chains layers in Python:
each layer dequantizes, evaluates, tanh-rescales, and re-quantizes through
jnp ops — the activations round-trip through f32 between every pair of
layers.  This module builds the deployed form of the same network for
``kernels.kan_spline.pipeline``: one static geometry plan for the whole
stack, zero-padded dequantized weights, and a single-jit executor in which
activations stay int codes across layer boundaries (the boundary requantizer
runs inside the producing kernel).

    qparams_list = quantize_kan_network(params_list, kspec)
    dep = deploy_kan_network(qparams_list, kspec, batch=B)
    y = kan_network_deploy_apply(dep, x, interpret=True)   # == ref path

The reference composition (``backend="ref"``) stays available for
conformance: it is exactly the layered ``kan_layer_apply_quantized`` +
tanh-rescale chain the Pallas path is validated against.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .asp_quant import ASPQuantSpec, quantize_input
from .kan_layer import KANSpec, kan_layer_apply_quantized, quantize_kan_layer
from ..kernels.kan_spline.pipeline import (
    PipelinePlan,
    kan_pipeline,
    make_pipeline_plan,
    pad_layer_weights,
)

__all__ = [
    "DeployedKAN",
    "quantize_kan_network",
    "deploy_kan_network",
    "deploy_kan_ffn_stack",
    "kan_network_deploy_apply",
    "kan_network_apply_ref",
    "default_interpret",
]


def default_interpret() -> bool:
    """Pallas kernels need interpret mode off-TPU (CPU containers, CI)."""
    return jax.default_backend() != "tpu"


@dataclasses.dataclass
class DeployedKAN:
    """A quantized KAN stack bound to a pipeline geometry plan.

    layers: tuple of {"lut", "wc", "wb"} with weights already padded to the
    plan (dequantized f32 — the values the int8 storage decodes to).
    specs/dims describe the logical network for the ref backend.
    """

    plan: PipelinePlan
    layers: tuple
    specs: tuple
    dims: tuple
    residual_raw: bool = False

    def replan(self, batch: int) -> "DeployedKAN":
        """Rebind to a new batch size (weights/padding are batch-agnostic)."""
        if batch == self.plan.b:
            return self
        plan = make_pipeline_plan(
            batch, self.dims, self.specs, residual_raw=self.residual_raw
        )
        return dataclasses.replace(self, plan=plan)


def quantize_kan_network(params_list, kspec: KANSpec):
    """Post-training-quantize every layer of a KAN stack (host-side)."""
    spec = kspec.layer_spec()
    return [quantize_kan_layer(p, spec) for p in params_list]


def _dequant_layer(qp: dict) -> tuple:
    wc = qp["c_q"].astype(jnp.float32) * qp["c_scale"]
    wb = qp["w_b_q"].astype(jnp.float32) * qp["w_b_scale"]
    return wc, wb


def deploy_kan_network(
    qparams_list, kspec: KANSpec, *, batch: int = 8
) -> DeployedKAN:
    """Bind a quantized KAN stack (single shared spec) to a pipeline plan."""
    spec = kspec.layer_spec()
    specs = tuple(spec for _ in qparams_list)
    dims = tuple(kspec.dims)
    return _deploy(qparams_list, dims, specs, batch, residual_raw=False)


def deploy_kan_ffn_stack(
    qparams_list, dims: tuple, spec: ASPQuantSpec, *, batch: int = 8
) -> DeployedKAN:
    """Bind a KANLinear chain with the raw-input ReLU branch (FFN contract)."""
    specs = tuple(spec for _ in qparams_list)
    return _deploy(qparams_list, tuple(dims), specs, batch, residual_raw=True)


def _deploy(qparams_list, dims, specs, batch, *, residual_raw) -> DeployedKAN:
    if len(dims) != len(qparams_list) + 1:
        raise ValueError(f"dims {dims} vs {len(qparams_list)} layers")
    plan = make_pipeline_plan(batch, dims, specs, residual_raw=residual_raw)
    layers = []
    for qp, lp in zip(qparams_list, plan.layers):
        wc, wb = _dequant_layer(qp)
        if wc.shape != (lp.f, lp.spec.num_basis, lp.o):
            raise ValueError(f"layer weights {wc.shape} != plan {lp}")
        padded = pad_layer_weights(wc, wb, lp)
        layers.append({"lut": qp["lut"], **padded})
    return DeployedKAN(
        plan=plan, layers=tuple(layers), specs=specs, dims=dims,
        residual_raw=residual_raw,
    )


def kan_network_deploy_apply(
    dep: DeployedKAN,
    x: jax.Array,
    *,
    xraw: jax.Array | None = None,
    interpret: bool | None = None,
    return_intermediates: bool = False,
):
    """Run float input x (B, F0) through the fused Pallas pipeline.

    Entry coding matches the layered reference: ``quantize_input(x, spec0)``
    for KAN stacks; FFN stacks (residual_raw) quantize ``tanh(x)`` and feed
    the raw x to the ReLU branch.
    """
    if interpret is None:
        interpret = default_interpret()
    dep = dep.replan(x.shape[0])
    spec0 = dep.specs[0]
    if dep.residual_raw:
        xraw = x.astype(jnp.float32) if xraw is None else xraw
        codes = quantize_input(jnp.tanh(xraw), spec0)
    else:
        codes = quantize_input(x, spec0)
        xraw = None
    return kan_pipeline(
        codes, xraw, dep.layers, dep.plan, interpret=interpret,
        return_intermediates=return_intermediates,
    )


def kan_network_apply_ref(qparams_list, x: jax.Array, kspec: KANSpec):
    """The layered jnp reference the pipeline is bit-exact against."""
    spec = kspec.layer_spec()
    h = x
    n = len(qparams_list)
    for li in range(n):
        h = kan_layer_apply_quantized(qparams_list[li], h, spec)
        if li < n - 1:
            h = jnp.tanh(h) * (0.5 * (spec.hi - spec.lo)) + 0.5 * (spec.hi + spec.lo)
    return h
