"""RRAM-ACIM behavioral simulator (paper §2.2, §3.3, Fig. 12).

Models the analog MAC  y[c] = sum_r x[r] * w[r, c]  executed on word-line
drives ``x`` (B(X) codes through the TM-DV input generator) against int8
conductance weights ``w``, with the non-idealities the paper calibrates from
TSMC 22nm RRAM-ACIM prototype measurements:

  * **IR-drop** on the bit line: systematic attenuation of a cell's effective
    contribution growing with (a) its physical distance from the BL clamp and
    (b) total column current (longer/busier BLs drop more).  Scales with
    array size — the paper's Fig. 12 sweeps 128..1024 rows.
  * **Input-generator noise** (TM-DV / pure-voltage / pure-PWM), see tmdv.py.
  * **Partial-sum error**: per-array Gaussian on the analog sum, std
    calibrated to grow with sqrt(rows) (more cells -> more accumulated
    device noise), plus ADC quantization of each array's partial sum.

KAN-SAM enters as a physical row permutation (sam.py): the same logical MAC,
different physical placement, different IR-drop exposure.

The hot loop (tiled int MAC + error injection) has a Pallas kernel under
``kernels/cim_mac``; this module is the pure-jnp reference and driver.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .tmdv import TMDVConfig, TD_A, apply_input_noise

__all__ = ["CIMConfig", "cim_matmul", "ideal_matmul", "irdrop_factors"]


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    """One RRAM-ACIM macro configuration."""

    array_rows: int = 128
    adc_bits: int = 8
    # IR-drop coefficient: fractional loss for the FARTHEST row of a
    # 128-row array at full column load (calibrated to Fig. 12's trend).
    ir_gamma: float = 0.04
    # Partial-sum noise std at 128 rows, in units of one LSB of input*weight.
    sigma_ps_ref: float = 1.0
    input_gen: TMDVConfig = dataclasses.field(default_factory=TD_A)
    deterministic: bool = False  # disable stochastic noise (IR-drop stays)

    def ir_scale(self) -> float:
        """IR-drop grows with BL length; sub-linear (sqrt) in rows because
        clamp drivers are upsized with array height (22nm chip trend)."""
        return self.ir_gamma * float(np.sqrt(self.array_rows / 128.0))

    def sigma_ps(self) -> float:
        return self.sigma_ps_ref * float(np.sqrt(self.array_rows / 128.0))


def irdrop_factors(cfg: CIMConfig, col_load: jax.Array) -> jax.Array:
    """Effective-weight attenuation (rows, cols).

    The systematic IR-drop model behind the paper's Fig. 12 array-size
    sweep (and the term KAN-SAM's §3.3 placement minimizes the residual
    of):

    factor[p, c] = 1 - ir_scale * ((p+1)/rows) * col_load[c]
    where physical row p=0 is nearest the clamp and col_load is the column's
    normalized current (0..1).
    """
    rows = cfg.array_rows
    dist = (jnp.arange(rows, dtype=jnp.float32) + 1.0) / rows
    return 1.0 - cfg.ir_scale() * dist[:, None] * col_load[None, :]


def ideal_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return x.astype(jnp.float32) @ w.astype(jnp.float32)


def cim_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: CIMConfig,
    key,
    row_perm=None,
    x_max: float | None = None,
    adc_calibrate: bool = False,
) -> jax.Array:
    """Simulated ACIM MAC — the paper's non-ideality evaluation regime
    (§2.2 circuit, Fig. 12/13 figures): statistics calibrated from the
    TSMC 22nm RRAM-ACIM prototype measurements, applied to the ideal
    x @ w in code domain.

    Args:
      x: (B, R) non-negative WL input codes (float or int), already in
        [0, 2**input_gen.total_bits - 1] scale.
      w: (R, C) weights (int8-scale floats or ints).
      cfg: macro config.
      key: PRNG for stochastic noise.
      row_perm: optional (R,) physical placement, perm[p] = logical row at
        physical position p (KAN-SAM).  None -> natural order.
      x_max: full-scale input code (for ADC ranging); default from input_gen.

    Returns:
      (B, C) float32 MAC result in the same scale as ideal x @ w.
    """
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0]
    bsz, r_total = x.shape
    cols = w.shape[1]
    rows = cfg.array_rows
    if x_max is None:
        x_max = float(2**cfg.input_gen.total_bits - 1)

    if row_perm is not None:
        perm = jnp.asarray(row_perm)
        x = jnp.take(x, perm, axis=1)
        w = jnp.take(w, perm, axis=0)

    # pad logical rows up to a multiple of the array height
    n_arrays = -(-r_total // rows)
    pad = n_arrays * rows - r_total
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))

    xt = x.reshape(bsz, n_arrays, rows).astype(jnp.float32)
    wt = w.reshape(n_arrays, rows, cols).astype(jnp.float32)

    k_in, k_ps = jax.random.split(key)
    if cfg.deterministic:
        x_eff = xt
    else:
        x_eff = apply_input_noise(xt, cfg.input_gen, k_in)

    # column load: average fraction of full-scale current this column sinks
    w_amax = jnp.maximum(jnp.abs(wt).max(), 1e-9)
    col_load = (
        jnp.einsum("bar,arc->ac", xt / x_max, jnp.abs(wt) / w_amax) / (rows * bsz)
    )  # (arrays, cols): batch-mean column current
    # normalize to the mean active column so ir_gamma is the attenuation of
    # the FARTHEST row of a TYPICALLY-loaded column (chip-measurement units)
    col_load = col_load / jnp.maximum(col_load.mean(), 1e-12)
    dist = (jnp.arange(rows, dtype=jnp.float32) + 1.0) / rows
    factor = 1.0 - cfg.ir_scale() * dist[None, :, None] * col_load[:, None, :]
    factor = jnp.clip(factor, 0.0, 1.0)  # attenuation is physical: [0, 1]
    w_eff = wt * factor  # (arrays, rows, cols)

    partial = jnp.einsum("bar,arc->bac", x_eff, w_eff)

    if not cfg.deterministic:
        partial = partial + cfg.sigma_ps() * x_max * jax.random.normal(
            k_ps, partial.shape
        )

    # digital calibration (standard at deployment): the MEAN attenuation of a
    # column is deterministic and compensated by a per-column scale; what
    # remains — and what KAN-SAM minimizes — is the row-placement-dependent
    # residual.
    mean_dist = float((rows + 1) / (2 * rows))
    comp = 1.0 - cfg.ir_scale() * mean_dist * col_load  # (arrays, cols)
    partial = partial / jnp.maximum(comp, 1e-3)[None]

    # per-array ADC: quantize the partial sum over its full-scale range.
    # worst-case ranging (x_max * sum|w|) is hugely pessimistic for sparse
    # KAN drives; real macros calibrate the ADC range to observed partials.
    if adc_calibrate:
        ideal_partial = jnp.einsum("bar,arc->bac", xt, wt)
        fs = 1.25 * jnp.maximum(jnp.abs(ideal_partial).max(axis=0), 1e-9)
    else:
        fs = x_max * jnp.maximum(jnp.abs(wt).sum(axis=1), 1e-9)  # (arrays, cols)
    lsb = 2.0 * fs / (2**cfg.adc_bits)
    partial = jnp.clip(partial, -fs[None], fs[None])
    partial = jnp.round(partial / lsb[None]) * lsb[None]

    return partial.sum(axis=1)
