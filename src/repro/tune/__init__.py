"""repro.tune: hardware-aware co-design autotuner (paper §3.4, scaled up).

Three cooperating pieces:

  * :mod:`~repro.tune.space` + :mod:`~repro.tune.search` — a declarative
    design space over the paper's knobs (ASP bit width, B-spline G/K, TM-DV
    voltage/time split, KAN-SAM on/off, ACIM array geometry) and a
    deterministic seedable multi-objective search returning a Pareto front
    over (area, energy, latency, accuracy), scored by the calibrated cost
    model and the ``acim`` runtime backend.
  * :mod:`~repro.tune.tiles` — an empirical Pallas tile autotuner that
    sweeps ``(bb, bo, bf)`` for a deployed network, gates candidates on
    bit-exactness, and registers the measured winner with the runtime plan
    cache so every consumer picks it up transparently.
  * :mod:`~repro.tune.artifact` — versioned JSON tuning artifacts (space
    hash, seed, front, chosen point, tile plan) that
    ``launch.serve --tuned-config`` and the examples load, so a tuned
    deployment reproduces from a file instead of a re-search.

    from repro import tune
    task = tune.make_knot_task()
    result = tune.pareto_search(task, tune.DesignSpace(), constraints=hc)
    chosen = tune.select_point(result.front)
    _, _, dep = tune.deploy_candidate(task, chosen.candidate)
    tile = tune.tune_tiles(dep)
    art = tune.build_tuning_artifact(search=result, chosen=chosen, tile=tile)
    tune.save_tuning_artifact("TUNE_artifact.json", art)
"""

from .artifact import (
    ARTIFACT_KIND,
    ARTIFACT_VERSION,
    apply_tuning_artifact,
    build_tuning_artifact,
    load_tuning_artifact,
    save_tuning_artifact,
)
from .search import (
    OBJECTIVE_DIRECTIONS,
    EvaluatedPoint,
    KnotTask,
    SearchConfig,
    SearchResult,
    deploy_candidate,
    dominates,
    evaluate_candidate,
    make_knot_task,
    pareto_front,
    pareto_search,
    select_point,
)
from .space import (
    Candidate,
    DesignSpace,
    candidate_from_dict,
    default_candidate,
    space_hash,
)
from .tiles import (
    TileTrial,
    TileTuneResult,
    enumerate_tile_candidates,
    plan_cost_proxy,
    tune_tiles,
)

__all__ = [
    "ARTIFACT_KIND",
    "ARTIFACT_VERSION",
    "Candidate",
    "DesignSpace",
    "EvaluatedPoint",
    "KnotTask",
    "OBJECTIVE_DIRECTIONS",
    "SearchConfig",
    "SearchResult",
    "TileTrial",
    "TileTuneResult",
    "apply_tuning_artifact",
    "build_tuning_artifact",
    "candidate_from_dict",
    "default_candidate",
    "deploy_candidate",
    "dominates",
    "enumerate_tile_candidates",
    "evaluate_candidate",
    "load_tuning_artifact",
    "make_knot_task",
    "pareto_front",
    "pareto_search",
    "plan_cost_proxy",
    "save_tuning_artifact",
    "select_point",
    "space_hash",
    "tune_tiles",
]
