"""Empirical Pallas tile autotuner for the fused KAN pipeline.

``make_pipeline_plan`` picks ``(bb, bo, bf)`` by a fixed heuristic; this
module *measures* instead: it sweeps valid tile overrides for a deployed
network's geometry, checks each candidate plan against the heuristic plan
for bit-exactness (outputs AND boundary codes — tile geometry must never
change the numbers, only the schedule), times the survivors, and registers
the winner with the runtime plan cache so every consumer
(``DeployedKAN.replan``, the executors, the serving path) transparently
runs on the tuned geometry.

Two scoring modes:

  * **measured** (on TPU): median-of-k wall-clock of the jitted fused
    pipeline per candidate — the real autotuner.
  * **proxy** (interpret mode, i.e. CI/CPU): interpret-mode wall-clock is
    noise dominated by Python dispatch, so candidates are ranked by a
    deterministic cost proxy (grid-cell dispatch overhead + padded-batch
    waste) instead; the sweep still executes every candidate once for the
    bit-exactness gate, so CI validates the full mechanism with a stable
    winner.

Candidates never change the padded dims ``fp``/``op`` (enforced by
``make_pipeline_plan``'s override validation), so weight bundles padded
under the heuristic plan remain valid verbatim — registering a tuned plan
is a schedule swap, not a redeploy.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.kan_spline.pipeline import (
    PipelinePlan,
    kan_pipeline,
    make_pipeline_plan,
    normalize_tile_overrides,
    validate_plan,
)
from ..runtime.executor import default_interpret
from ..runtime.plancache import PLAN_CACHE, bucket_batch

__all__ = [
    "TileTrial",
    "TileTuneResult",
    "enumerate_tile_candidates",
    "plan_cost_proxy",
    "tune_tiles",
]


@dataclasses.dataclass(frozen=True)
class TileTrial:
    """One swept tile candidate and what happened to it."""

    overrides: tuple          # per-layer ((bb, bo, bf), ...)
    valid: bool
    exact: bool
    score: float              # us (measured) or proxy units; inf if rejected
    reason: str = ""          # why it was rejected, if it was


@dataclasses.dataclass
class TileTuneResult:
    dims: tuple
    specs: tuple
    residual_raw: bool
    bucket: int
    mode: str                 # "measured" | "proxy"
    heuristic_plan: PipelinePlan
    heuristic_score: float
    chosen_overrides: tuple | None   # None -> heuristic won
    chosen_plan: PipelinePlan
    trials: tuple             # tuple[TileTrial]
    registered: bool

    @property
    def tuned(self) -> bool:
        return self.chosen_overrides is not None

    def to_dict(self) -> dict:
        return {
            "dims": list(self.dims),
            "residual_raw": bool(self.residual_raw),
            "bucket": int(self.bucket),
            "mode": self.mode,
            "specs": [dataclasses.asdict(s) for s in self.specs],
            "overrides": None if self.chosen_overrides is None
            else [list(t) for t in self.chosen_overrides],
            "heuristic_score": float(self.heuristic_score),
            "n_trials": len(self.trials),
        }


def _heuristic_overrides(plan: PipelinePlan) -> tuple:
    return tuple((lp.bb, lp.bo, lp.bf) for lp in plan.layers)


def enumerate_tile_candidates(
    plan: PipelinePlan,
    *,
    max_candidates: int = 16,
    seed: int = 0,
) -> list:
    """Valid (by construction) tile-override candidates for a plan's shape.

    Sweeps the batch block, the output block and a per-layer shrink of the
    contraction block, constrained to power-of-two divisors of the plan's
    padded dims.  The heuristic's own blocks are always candidate 0 so the
    tuner can conclude "heuristic wins".  Deterministically subsampled to
    ``max_candidates`` under ``seed``.
    """
    heur = _heuristic_overrides(plan)
    bb_h = plan.layers[0].bb
    bb_opts = sorted({bb for bb in (8, 16, 32, 64, 128, 256)
                      if bb <= max(plan.bp, bb_h)} | {bb_h})
    bo_opts = (128, 64, 32)
    bf_shifts = (0, 1, 2)

    cands = [heur]
    for bb in bb_opts:
        for bo in bo_opts:
            for shift in bf_shifts:
                ov = []
                ok = True
                for lp in plan.layers:
                    bo_c = min(bo, lp.op)
                    while lp.op % bo_c:
                        bo_c //= 2
                    bf_c = max(8, lp.bf >> shift)
                    if lp.fp % bf_c or bo_c < 8:
                        ok = False
                        break
                    ov.append((bb, bo_c, bf_c))
                if ok:
                    ov = tuple(ov)
                    if ov not in cands:
                        cands.append(ov)
    extra = cands[1:]
    if len(extra) > max_candidates - 1:
        rng = np.random.default_rng(seed)
        keep = sorted(rng.choice(len(extra), size=max_candidates - 1,
                                 replace=False).tolist())
        extra = [extra[i] for i in keep]
    return [heur] + extra


def plan_cost_proxy(plan: PipelinePlan) -> float:
    """Deterministic stand-in for wall-clock when timing is meaningless.

    Models the two things tiling actually changes at fixed padded dims:
    per-tile dispatch overhead (finer grids pay more fixed cost) and
    padded-batch waste (``bp`` grows with ``bb``).  Compute volume itself is
    tile-invariant, so it enters only through ``bp``.
    """
    C0 = 4096.0  # fixed per-tile dispatch/prologue cost, flop-equivalents
    total = 0.0
    for lp in plan.layers:
        nb = lp.spec.num_basis
        cells = (plan.bp // lp.bb) * (lp.op // lp.bo) * (lp.fp // lp.bf)
        tile_work = lp.bb * lp.bf * nb * (1.0 + lp.bo)  # basis build + MAC
        total += cells * (C0 + tile_work)
    return total


def _sample_inputs(plan: PipelinePlan, seed: int):
    """Deterministic entry codes (+ raw activations) at the plan's bucket."""
    rng = np.random.default_rng(seed)
    spec0 = plan.layers[0].spec
    codes = jnp.asarray(
        rng.integers(0, spec0.num_codes, size=(plan.b, plan.layers[0].f)),
        jnp.int32,
    )
    xraw = None
    if plan.layers[0].residual_raw:
        xraw = jnp.asarray(
            rng.standard_normal((plan.b, plan.layers[0].f)), jnp.float32
        )
    return codes, xraw


def _run_plan(codes, xraw, layers, plan, interpret):
    y, bcodes = kan_pipeline(codes, xraw, layers, plan, interpret=interpret,
                             return_intermediates=True)
    return np.asarray(y), tuple(np.asarray(c) for c in bcodes)


def _time_plan(codes, xraw, layers, plan, interpret, repeats) -> float:
    """Median-of-repeats wall-clock (us) of the jitted fused pipeline."""
    fn = lambda: kan_pipeline(codes, xraw, layers, plan, interpret=interpret)
    fn().block_until_ready()  # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn().block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def tune_tiles(
    dep,
    *,
    batch: int | None = None,
    candidates=None,
    max_candidates: int = 16,
    repeats: int = 5,
    interpret: bool | None = None,
    seed: int = 0,
    register: bool = True,
    warm: bool = True,
    score_fn=None,
) -> TileTuneResult:
    """Sweep tile geometries for a deployed KAN; register the winner.

    ``dep`` is a :class:`~repro.core.kan_network_deploy.DeployedKAN`; the
    sweep runs at the batch bucket of ``batch`` (default: the bundle's bound
    batch).  Every candidate is validated (:func:`validate_plan`) and gated
    on bit-exact outputs + boundary codes vs the heuristic plan before it
    may win.  With ``register=True`` the winning overrides are installed in
    the runtime plan cache (a no-op when the heuristic wins) and — with
    ``warm=True`` — the pallas executor entry is re-traced once here, so
    consumers keep hitting the cache with zero traces of their own.

    ``score_fn(plan) -> float`` replaces the scoring entirely when given
    (candidates are still validated and exactness-gated) — used by tests
    and by callers with an external performance model.  Note the default
    proxy is minimized by the heuristic's maximal blocks by construction,
    so in interpret mode the tuner honestly reports "heuristic wins"; real
    re-tiling wins come from the measured mode on TPU.
    """
    if interpret is None:
        interpret = default_interpret()
    mode = "proxy" if interpret else "measured"
    dims, specs, residual_raw = tuple(dep.dims), tuple(dep.specs), \
        dep.residual_raw
    bucket = bucket_batch(batch if batch is not None else dep.plan.b)

    # the pure heuristic baseline, independent of any registered overrides
    heur_plan = make_pipeline_plan(bucket, dims, specs,
                                   residual_raw=residual_raw)
    codes, xraw = _sample_inputs(heur_plan, seed)
    y_ref, codes_ref = _run_plan(codes, xraw, dep.layers, heur_plan,
                                 interpret)

    if candidates is None:
        candidates = enumerate_tile_candidates(
            heur_plan, max_candidates=max_candidates, seed=seed)
    heur_ov = _heuristic_overrides(heur_plan)
    n_layers = len(dims) - 1
    normed = []
    for c in candidates:
        try:
            nc = normalize_tile_overrides(c, n_layers)
        except ValueError:
            nc = tuple(tuple(t) for t in c)  # keep malformed; trial rejects
        if nc not in normed:
            normed.append(nc)
    if heur_ov not in normed:
        normed.insert(0, heur_ov)  # the baseline must always compete
    candidates = normed

    trials = []
    scored = []  # (score, order_index, overrides, plan)
    for idx, ov in enumerate(candidates):
        try:
            plan_c = make_pipeline_plan(bucket, dims, specs,
                                        residual_raw=residual_raw,
                                        tile_overrides=ov)
            validate_plan(plan_c)
        except ValueError as e:
            trials.append(TileTrial(overrides=tuple(ov), valid=False,
                                    exact=False, score=float("inf"),
                                    reason=str(e)))
            continue
        y_c, codes_c = _run_plan(codes, xraw, dep.layers, plan_c, interpret)
        exact = np.array_equal(y_c, y_ref) and all(
            np.array_equal(a, b) for a, b in zip(codes_c, codes_ref)
        )
        if not exact:
            trials.append(TileTrial(overrides=tuple(ov), valid=True,
                                    exact=False, score=float("inf"),
                                    reason="not bit-exact vs heuristic"))
            continue
        if score_fn is not None:
            score = float(score_fn(plan_c))
        elif mode == "measured":
            score = _time_plan(codes, xraw, dep.layers, plan_c, interpret,
                               repeats)
        else:
            score = plan_cost_proxy(plan_c)
        trials.append(TileTrial(overrides=tuple(ov), valid=True, exact=True,
                                score=score))
        scored.append((score, idx, tuple(ov), plan_c))

    heur_score = next(t.score for t in trials
                      if t.overrides == heur_ov and t.exact)
    best_score, _, best_ov, best_plan = min(scored, key=lambda s: (s[0], s[1]))
    tuned = best_ov != heur_ov and best_score < heur_score

    registered = False
    if register:
        PLAN_CACHE.set_tile_overrides(
            dims, specs, residual_raw, best_ov if tuned else None
        )
        registered = tuned
        if tuned and warm:
            # re-trace the consumer-visible executor entry HERE so callers
            # of the serving/deploy surfaces get pure cache hits afterwards
            from .. import runtime

            rng = np.random.default_rng(seed)
            spec0 = specs[0]
            x = jnp.asarray(
                rng.uniform(spec0.lo, spec0.hi, size=(bucket, dims[0])),
                jnp.float32,
            )
            runtime.execute(dep, x, backend="pallas", interpret=interpret)

    return TileTuneResult(
        dims=dims, specs=specs, residual_raw=residual_raw, bucket=bucket,
        mode=mode,
        heuristic_plan=heur_plan, heuristic_score=heur_score,
        chosen_overrides=best_ov if tuned else None,
        chosen_plan=best_plan if tuned else heur_plan,
        trials=tuple(trials),
        registered=registered,
    )
