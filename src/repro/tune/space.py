"""Declarative co-design search space (the knobs of the paper's techniques).

One :class:`Candidate` is a complete hardware/algorithm operating point:

  * ``grid_size`` / ``order``  — the B-spline basis (G, K); more grid means
    more accuracy AND more RRAM rows/LUT demux throws (paper Fig. 9/13).
  * ``n_bits``                 — ASP system bit width; PowerGap (eq. (6))
    requires ``G * 2**LD <= 2**n`` with LD >= 0, checked by validity.
  * ``voltage_bits``           — the TM-DV N:1 split of the WL input
    generator (paper §3.2): more voltage bits -> fewer time slots (faster,
    less WL drive energy) but tighter DAC noise margins (sigma_v grows).
  * ``array_rows`` / ``adc_bits`` — ACIM macro geometry (cost model +
    partial-sum/IR-drop statistics both scale with rows).
  * ``use_sam``                — KAN-SAM sparsity-aware row placement on/off
    (paper §3.3): a free permutation that trades nothing in cost for a
    smaller IR-drop residual.

:class:`DesignSpace` is a plain axes->choices table with deterministic,
seedable sampling and one-axis neighborhood mutation — the proposal
machinery :func:`repro.tune.search.pareto_search` iterates on.  The space
hash fingerprints the axes so a tuning artifact records exactly which space
produced it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

from ..core.asp_quant import ASPQuantSpec, max_ld
from ..core.cim import CIMConfig
from ..core.tmdv import TMDVConfig

__all__ = [
    "Candidate",
    "DesignSpace",
    "default_candidate",
    "candidate_from_dict",
    "space_hash",
]


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One co-design operating point (hashable; the search's genotype).

    ``layer_bits`` is the mixed-precision axis (KANtize-style): one ASP bit
    width per layer, each independently PowerGap-checked against
    ``grid_size``; ``()`` means uniform ``n_bits``.  Layers at <= 4 bits
    deploy int4-packed (two weight codes per int8 lane) and are costed at
    the narrower cell footprint.
    """

    grid_size: int = 5
    order: int = 3
    n_bits: int = 8
    layer_bits: tuple = ()
    voltage_bits: int = 4
    array_rows: int = 128
    adc_bits: int = 8
    use_sam: bool = False

    def __post_init__(self):
        # JSON round trips (artifacts) hand lists back; keep it hashable
        if not isinstance(self.layer_bits, tuple):
            object.__setattr__(self, "layer_bits",
                               tuple(int(b) for b in self.layer_bits))

    def bits_for(self, n_layers: int) -> tuple:
        """Resolved per-layer widths (uniform ``n_bits`` when unset)."""
        if self.layer_bits:
            return self.layer_bits
        return (self.n_bits,) * n_layers

    def spec(self, lo: float = -1.0, hi: float = 1.0) -> ASPQuantSpec:
        """The ASP quantization grid this point deploys with."""
        return ASPQuantSpec(
            grid_size=self.grid_size, order=self.order, n_bits=self.n_bits,
            lut_bits=self.n_bits, lo=lo, hi=hi,
        )

    def input_gen(self, sigma_v_ref: float = 0.015,
                  sigma_t: float = 0.08) -> TMDVConfig:
        """The WL input-generator config (TM-DV split of ``n_bits``)."""
        return TMDVConfig(
            total_bits=self.n_bits, voltage_bits=self.voltage_bits,
            sigma_v_ref=sigma_v_ref, sigma_t=sigma_t,
        )

    def cim_config(self, ir_gamma: float = 0.06,
                   sigma_ps_ref: float = 0.05) -> CIMConfig:
        """The ACIM macro config at the given measured calibration."""
        return CIMConfig(
            array_rows=self.array_rows, adc_bits=self.adc_bits,
            ir_gamma=ir_gamma, sigma_ps_ref=sigma_ps_ref,
            input_gen=self.input_gen(),
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def candidate_from_dict(d: dict) -> Candidate:
    fields = {f.name for f in dataclasses.fields(Candidate)}
    return Candidate(**{k: v for k, v in d.items() if k in fields})


def default_candidate() -> Candidate:
    """The repo's un-searched deployment defaults (KAN1 as shipped):
    G=5, K=3, 8-bit ASP, 4:4 TM-DV split, 128-row arrays, 8-bit ADC,
    no SAM.  The baseline the Pareto front is measured against."""
    return Candidate()


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Axes -> choices.  Every axis mirrors a :class:`Candidate` field."""

    grid_size: tuple = (3, 5, 8, 12)
    order: tuple = (3,)
    n_bits: tuple = (8,)
    # per-layer bit allocations (whole tuples are the choices); () = uniform.
    # NOTE: mixed allocations must be PowerGap-valid against the sampled
    # grid_size — ``sample``/``neighbors`` REJECT invalid combinations
    # (never clamp), so e.g. (4, 8) with grid_size 32 simply never appears.
    layer_bits: tuple = ((),)
    voltage_bits: tuple = (2, 3, 4, 5, 6)
    array_rows: tuple = (128, 256)
    adc_bits: tuple = (8,)
    use_sam: tuple = (False, True)

    def axes(self) -> dict:
        return {f.name: tuple(getattr(self, f.name))
                for f in dataclasses.fields(self)}

    def size(self) -> int:
        n = 1
        for choices in self.axes().values():
            n *= len(choices)
        return n

    # -- validity --------------------------------------------------------

    def is_valid(self, cand: Candidate) -> bool:
        """Structural validity (independent of space membership)."""
        if cand.order < 1 or cand.grid_size < 1:
            return False
        # every deployed width — mixed per-layer or the uniform n_bits —
        # must satisfy PowerGap: G * 2**LD <= 2**n with LD >= 0 (eq. (6)),
        # and the TM-DV split cannot exceed the narrowest layer's width
        widths = (cand.n_bits,) + tuple(cand.layer_bits)
        for b in widths:
            if b < 2 or b > 16 or max_ld(cand.grid_size, b) < 0:
                return False
        if cand.voltage_bits < 0 or cand.voltage_bits > min(widths):
            return False
        return True

    def contains(self, cand: Candidate) -> bool:
        return all(getattr(cand, name) in choices
                   for name, choices in self.axes().items())

    # -- deterministic proposals ----------------------------------------

    def sample(self, rng, n: int) -> list:
        """n valid random candidates (rejection sampling, seeded rng)."""
        out = []
        axes = self.axes()
        tries = 0
        while len(out) < n and tries < 64 * max(n, 1):
            tries += 1
            cand = Candidate(**{
                name: choices[int(rng.integers(len(choices)))]
                for name, choices in axes.items()
            })
            if self.is_valid(cand):
                out.append(cand)
        return out

    def neighbors(self, cand: Candidate, rng, n: int = 2) -> list:
        """Mutate ONE axis to an adjacent choice, n times (seeded rng)."""
        axes = [(name, choices) for name, choices in self.axes().items()
                if len(choices) > 1]
        out = []
        tries = 0
        while len(out) < n and axes and tries < 32 * max(n, 1):
            tries += 1
            name, choices = axes[int(rng.integers(len(axes)))]
            cur = getattr(cand, name)
            idx = choices.index(cur) if cur in choices \
                else int(rng.integers(len(choices)))
            step = 1 if rng.integers(2) else -1
            nxt = choices[max(0, min(len(choices) - 1, idx + step))]
            if nxt == cur:
                continue
            prop = dataclasses.replace(cand, **{name: nxt})
            if self.is_valid(prop):
                out.append(prop)
        return out


def space_hash(space: DesignSpace) -> str:
    """Stable fingerprint of the axes (recorded in tuning artifacts)."""
    blob = json.dumps(space.axes(), sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]
