"""Deterministic multi-objective co-design search (Pareto, not argmax).

The paper's headline numbers come from *searching* the KAN/quantization/
mapping space under hardware constraints (§3.4, Fig. 9).  This module runs
that search as a seedable NSGA-II-lite loop:

  * **proposals** — random samples + one-axis neighborhood mutations of the
    current front (:class:`~repro.tune.space.DesignSpace`), deduplicated;
  * **cost**      — :func:`repro.core.neurosim.kan_cost` (the 22nm-calibrated
    accelerator model) gives area/energy/latency; candidates violating the
    :class:`~repro.core.neurosim.HardwareConstraints` are recorded but never
    enter the front;
  * **quality**   — task accuracy measured on the ``acim`` runtime backend
    (the fused Pallas pipeline with the paper's RRAM non-idealities at the
    candidate's TM-DV split / array geometry / SAM placement), averaged over
    a fixed set of PRNG seeds so the whole search is reproducible;
  * **result**    — a Pareto FRONT over (area, energy, latency, accuracy),
    not a single point; callers pick an operating point per deployment
    budget (:func:`select_point`) and freeze it into a tuning artifact.

Per-candidate accuracy does NOT retrain: one float base network is trained
once per task, and each candidate's (G, K) basis is least-squares-refit from
it (:func:`repro.core.kan_layer.refit_layer_spec`) before ASP quantization —
refit-down loses fidelity, refit-up keeps it, which is exactly the
accuracy/cost trade the search is exploring.
"""

from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from ..core.asp_quant import dequantize_input
from ..core.kan_layer import KANSpec, refit_layer_spec
from ..core.kan_network_deploy import (
    deploy_kan_network,
    kan_network_deploy_apply,
    quantize_kan_network,
)
from ..core.neurosim import (
    HardwareConstraints,
    check_constraints,
    kan_cost,
    train_kan,
)
from ..core.sam import row_activation_weight, sam_permutation
from ..runtime.executor import default_interpret
from .space import Candidate, DesignSpace, default_candidate, space_hash

__all__ = [
    "OBJECTIVE_DIRECTIONS",
    "EvaluatedPoint",
    "SearchConfig",
    "SearchResult",
    "KnotTask",
    "make_knot_task",
    "deploy_candidate",
    "evaluate_candidate",
    "dominates",
    "pareto_front",
    "pareto_search",
    "select_point",
]

# +1.0 -> minimize, -1.0 -> maximize
OBJECTIVE_DIRECTIONS = {
    "area_mm2": 1.0,
    "energy_pj": 1.0,
    "latency_ns": 1.0,
    "phases": 1.0,
    "accuracy": -1.0,
}


@dataclasses.dataclass(frozen=True)
class EvaluatedPoint:
    """One scored candidate: the search's phenotype."""

    candidate: Candidate
    metrics: dict
    feasible: bool = True

    def to_dict(self) -> dict:
        return {
            "config": self.candidate.to_dict(),
            "metrics": {k: float(v) for k, v in self.metrics.items()},
            "feasible": bool(self.feasible),
        }


def dominates(a: dict, b: dict, objectives: tuple) -> bool:
    """True iff metrics ``a`` Pareto-dominates ``b`` on ``objectives``
    (every objective at least as good, at least one strictly better)."""
    better = False
    for name in objectives:
        sign = OBJECTIVE_DIRECTIONS[name]
        va, vb = sign * a[name], sign * b[name]
        if va > vb:
            return False
        if va < vb:
            better = True
    return better


def pareto_front(points, objectives: tuple) -> tuple:
    """Non-dominated subset of ``points`` (order-preserving)."""
    return tuple(
        p for p in points
        if not any(dominates(q.metrics, p.metrics, objectives)
                   for q in points if q is not p)
    )


# ----------------------------------------------------------------------------
# Task: what "accuracy" means for a candidate
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class KnotTask:
    """A trained base network + eval data: the quality oracle of the search.

    ``base_params`` is the float network trained ONCE at ``base_kspec``;
    candidates are refit from it.  ``calib_x`` feeds KAN-SAM placement and
    per-layer activation statistics.  ``ir_gamma``/``sigma_ps_ref`` are the
    measured 22nm non-ideality calibration every candidate is scored under.
    """

    dims: tuple
    base_kspec: KANSpec
    base_params: list
    x_val: jax.Array
    y_val: np.ndarray
    calib_x: jax.Array
    ir_gamma: float = 0.06
    sigma_ps_ref: float = 0.05
    name: str = "knot"


def make_knot_task(
    n_train: int = 4096,
    n_val: int = 512,
    epochs: int = 40,
    seed: int = 0,
    dims: tuple = (17, 1, 14),
    base_grid: int = 8,
    base_order: int = 3,
    lr: float = 1.5e-2,
    label_noise: float = 0.04,
    calib_n: int = 256,
    ir_gamma: float = 0.06,
    sigma_ps_ref: float = 0.05,
    verbose: bool = False,
) -> KnotTask:
    """Train the shared float base network on the knot surrogate (once)."""
    from ..data.knot import make_knot_dataset

    xt, yt, xv, yv = make_knot_dataset(n_train, n_val, seed=seed,
                                       label_noise=label_noise)
    kspec = KANSpec(dims=tuple(dims), grid_size=base_grid, order=base_order)
    params, _ = train_kan(kspec, xt, yt, xv, yv, epochs=epochs,
                          batch_size=1024, lr=lr, seed=seed, verbose=verbose)
    return KnotTask(
        dims=tuple(dims), base_kspec=kspec, base_params=params,
        x_val=jnp.asarray(xv), y_val=np.asarray(yv),
        calib_x=jnp.asarray(xt[:calib_n]),
        ir_gamma=ir_gamma, sigma_ps_ref=sigma_ps_ref,
    )


def deploy_candidate(task: KnotTask, cand: Candidate):
    """Refit the base network to the candidate's basis, quantize, deploy.

    Returns (kspec, qparams, dep) — ``dep`` is batch-bound to the task's
    validation set and runs on any runtime backend.
    """
    kspec_c = KANSpec(
        dims=task.dims, grid_size=cand.grid_size, order=cand.order,
        n_bits=cand.layer_bits if cand.layer_bits else cand.n_bits,
        lut_bits=cand.n_bits,
    )
    base_spec = task.base_kspec.layer_spec()
    spec_c = kspec_c.layer_spec()
    if (spec_c.grid_size, spec_c.order) == (base_spec.grid_size,
                                            base_spec.order):
        params = task.base_params
    else:
        params = [refit_layer_spec(p, base_spec, spec_c)
                  for p in task.base_params]
    qparams = quantize_kan_network(params, kspec_c)
    dep = deploy_kan_network(qparams, kspec_c, batch=int(task.x_val.shape[0]))
    return kspec_c, qparams, dep


def _sam_perms(task: KnotTask, cand: Candidate, dep, kspec: KANSpec,
               interpret: bool) -> tuple:
    """Per-layer KAN-SAM placements from calibration activations.

    Layer 0 calibrates on the task's calibration inputs; deeper layers on
    the dequantized boundary codes an ideal (quantized, noise-free) pass
    emits — the same activation statistics the deployed chip would profile.
    """
    specs = kspec.layer_specs()
    _, codes = kan_network_deploy_apply(
        dep, task.calib_x, backend="ref", interpret=interpret,
        return_intermediates=True,
    )
    layer_inputs = [task.calib_x]
    for li, c in enumerate(codes):
        # boundary codes are emitted at the NEXT layer's input width
        layer_inputs.append(dequantize_input(c, specs[li + 1]))
    perms = []
    for li, f in enumerate(task.dims[:-1]):
        rw = row_activation_weight(layer_inputs[li], specs[li], f)
        perms.append(tuple(int(i) for i in
                           sam_permutation(rw, cand.array_rows)))
    return tuple(perms)


def _candidate_key(cand: Candidate, eval_seed: int):
    """Deterministic PRNG key per (candidate, eval_seed) — stable across
    runs and platforms (no reliance on python hash)."""
    digest = zlib.crc32(repr(cand).encode()) & 0x7FFFFFFF
    return jax.random.fold_in(jax.random.PRNGKey(eval_seed), digest)


def evaluate_candidate(
    task: KnotTask | None,
    cand: Candidate,
    *,
    acim_seeds: int = 2,
    eval_seed: int = 0,
    interpret: bool | None = None,
    dims: tuple = (17, 1, 14),
) -> dict:
    """Score one candidate: accelerator cost (+ acim accuracy with a task).

    With ``task=None`` this is a pure hardware design-space evaluation
    (area/energy/latency/phases only, on ``dims``) — the fast mode the
    step-1 constraint examples use.  With a task, accuracy is the mean over
    ``acim_seeds`` seeded runs of the ``acim`` backend at the candidate's
    TM-DV split, array geometry and (optionally) SAM placement.
    """
    metrics = dict(kan_cost(
        task.dims if task is not None else tuple(dims),
        cand.grid_size, cand.order, cand.n_bits,
        cand.input_gen(), cand.array_rows, cand.adc_bits,
        layer_bits=cand.layer_bits,
    ))
    if task is None:
        return metrics
    if interpret is None:
        interpret = default_interpret()
    kspec_c, _, dep = deploy_candidate(task, cand)
    sam_perms = (_sam_perms(task, cand, dep, kspec_c, interpret)
                 if cand.use_sam else None)
    cim = cand.cim_config(task.ir_gamma, task.sigma_ps_ref)
    key0 = _candidate_key(cand, eval_seed)
    accs = []
    for s in range(acim_seeds):
        logits = kan_network_deploy_apply(
            dep, task.x_val, interpret=interpret, backend="acim",
            cim=cim, sam_perms=sam_perms, key=jax.random.fold_in(key0, s),
        )
        accs.append(
            float((np.argmax(np.asarray(logits), -1) == task.y_val).mean())
        )
    metrics["accuracy"] = float(np.mean(accs))
    return metrics


# ----------------------------------------------------------------------------
# The search loop
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    budget: int = 24          # total candidate evaluations (incl. baseline)
    n_init: int = 6           # random seeding of the first round
    n_neighbors: int = 2      # mutations proposed per front member per round
    seed: int = 0             # proposal RNG seed
    eval_seed: int = 0        # accuracy-noise seed family
    acim_seeds: int = 2       # noise seeds averaged per accuracy estimate
    objectives: tuple | None = None  # None -> cost axes (+accuracy w/ task)
    interpret: bool | None = None    # None -> auto (off-TPU -> interpret)


@dataclasses.dataclass
class SearchResult:
    front: tuple              # tuple[EvaluatedPoint] — feasible, non-dominated
    evaluated: tuple          # every scored point, evaluation order
    baseline: EvaluatedPoint | None
    objectives: tuple
    seed: int
    space_hash: str
    n_evals: int
    calibration: dict | None = None  # non-ideality point accuracy was scored at

    def dominating_baseline(self, on: tuple = ("energy_pj", "accuracy")):
        """Front points that Pareto-dominate the baseline on ``on``."""
        if self.baseline is None:
            return ()
        return tuple(p for p in self.front
                     if dominates(p.metrics, self.baseline.metrics, on))

    def to_dict(self) -> dict:
        return {
            "objectives": list(self.objectives),
            "seed": self.seed,
            "space_hash": self.space_hash,
            "n_evals": self.n_evals,
            "calibration": self.calibration,
            "front": [p.to_dict() for p in self.front],
            "baseline": None if self.baseline is None
            else self.baseline.to_dict(),
        }


def pareto_search(
    task: KnotTask | None,
    space: DesignSpace,
    *,
    constraints: HardwareConstraints | None = None,
    config: SearchConfig | None = None,
    baseline: Candidate | None = None,
    dims: tuple = (17, 1, 14),
) -> SearchResult:
    """Run the co-design search; fully deterministic under a fixed config.

    ``baseline`` (default: the repo's un-searched deployment defaults) is
    always evaluated first so the front can be compared against it; pass a
    candidate of your own to rebase the comparison.  ``dims`` only matters
    for the task-free (cost-only) mode; with a task the task's dims rule.
    """
    cfg = config or SearchConfig()
    if cfg.objectives is not None:
        objectives = tuple(cfg.objectives)
    else:
        objectives = ("area_mm2", "energy_pj", "latency_ns")
        if task is not None:
            objectives += ("accuracy",)
    rng = np.random.default_rng(cfg.seed)
    if baseline is None:
        baseline = default_candidate()

    seen: dict = {}
    evaluated: list = []

    def eval_one(cand: Candidate):
        if cand in seen or not space.is_valid(cand):
            return None
        metrics = evaluate_candidate(
            task, cand, acim_seeds=cfg.acim_seeds,
            eval_seed=cfg.eval_seed, interpret=cfg.interpret, dims=dims,
        )
        feasible = constraints is None or check_constraints(metrics,
                                                            constraints)
        pt = EvaluatedPoint(candidate=cand, metrics=metrics,
                            feasible=feasible)
        seen[cand] = pt
        evaluated.append(pt)
        return pt

    base_pt = eval_one(baseline)
    for cand in space.sample(rng, cfg.n_init):
        if len(evaluated) >= cfg.budget:
            break
        eval_one(cand)

    while len(evaluated) < cfg.budget:
        front = pareto_front([p for p in evaluated if p.feasible],
                             objectives)
        proposals: list = []
        for p in front:
            proposals += space.neighbors(p.candidate, rng, cfg.n_neighbors)
        proposals += space.sample(rng, 2)
        fresh = [c for c in proposals if c not in seen]
        if not fresh:
            break
        for cand in fresh[: cfg.budget - len(evaluated)]:
            eval_one(cand)

    front = pareto_front([p for p in evaluated if p.feasible], objectives)
    front = tuple(sorted(front, key=lambda p: (p.metrics["energy_pj"],
                                               p.metrics["area_mm2"],
                                               repr(p.candidate))))
    calibration = None
    if task is not None:
        # the exact non-ideality point every accuracy above was scored at
        # (TMDV sigma refs come from Candidate.input_gen's defaults)
        from ..core.tmdv import TMDVConfig

        tm = TMDVConfig()
        calibration = {
            "ir_gamma": float(task.ir_gamma),
            "sigma_ps_ref": float(task.sigma_ps_ref),
            "sigma_v_ref": float(tm.sigma_v_ref),
            "sigma_t": float(tm.sigma_t),
        }
    return SearchResult(
        front=front,
        evaluated=tuple(evaluated),
        baseline=base_pt,
        objectives=objectives,
        seed=cfg.seed,
        space_hash=space_hash(space),
        n_evals=len(evaluated),
        calibration=calibration,
    )


def select_point(front, prefer: str = "accuracy") -> EvaluatedPoint:
    """Pick one operating point off a front.

    ``prefer="accuracy"``: highest accuracy, ties broken by lowest energy —
    the paper's "accuracy boost under the budget" reading.  Any other name
    minimizes that metric, ties broken by highest accuracy.
    """
    if not front:
        raise ValueError("empty Pareto front")
    if prefer == "accuracy":
        return max(front, key=lambda p: (p.metrics.get("accuracy", 0.0),
                                         -p.metrics["energy_pj"]))
    return min(front, key=lambda p: (p.metrics[prefer],
                                     -p.metrics.get("accuracy", 0.0)))
