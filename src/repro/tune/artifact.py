"""Versioned, deployable tuning artifacts (search + tile plan as a file).

A tuned deployment must be reproducible WITHOUT re-running the search: the
artifact freezes everything the runtime needs — the space fingerprint and
seed (provenance), the Pareto front and the chosen operating point
(quantization/mapping), and the tuned tile plan (schedule) — into one JSON
file that ``launch.serve --tuned-config``, ``ServeEngine`` setups and the
examples load at deploy time.

Schema (version 1)::

    {
      "kind": "repro.tune.artifact", "version": 1,
      "task": "knot", "seed": 0, "space_hash": "...",
      "calibration": {"ir_gamma": ..., "sigma_ps_ref": ...,
                      "sigma_v_ref": ..., "sigma_t": ...} | null,
      "objectives": [...],
      "front":    [{"config": {...}, "metrics": {...}, "feasible": true}],
      "baseline": {...} | null,
      "chosen":   {"config": {...}, "metrics": {...}} | null,
      "tile_plan": {
        "dims": [...], "residual_raw": false, "bucket": 32,
        "mode": "measured" | "proxy",
        "specs": [{"grid_size": ..., "order": ..., ...}],
        "overrides": [[bb, bo, bf], ...] | null
      } | null
    }

``apply_tuning_artifact`` re-installs the tile plan in the runtime plan
cache and resolves the chosen point back into live config objects
(:class:`~repro.core.asp_quant.ASPQuantSpec`,
:class:`~repro.core.tmdv.TMDVConfig`, :class:`~repro.core.cim.CIMConfig`),
so loading an artifact under the same seed reproduces the identical
deployment the tuner built.
"""

from __future__ import annotations

import dataclasses
import json

from ..core.asp_quant import ASPQuantSpec
from ..runtime.plancache import PLAN_CACHE
from .space import Candidate, candidate_from_dict

__all__ = [
    "ARTIFACT_KIND",
    "ARTIFACT_VERSION",
    "build_tuning_artifact",
    "save_tuning_artifact",
    "load_tuning_artifact",
    "apply_tuning_artifact",
]

ARTIFACT_KIND = "repro.tune.artifact"
ARTIFACT_VERSION = 1


def _spec_from_dict(d: dict) -> ASPQuantSpec:
    fields = {f.name for f in dataclasses.fields(ASPQuantSpec)}
    return ASPQuantSpec(**{k: v for k, v in d.items() if k in fields})


def build_tuning_artifact(
    *,
    search=None,          # SearchResult | None
    chosen=None,          # EvaluatedPoint | None
    tile=None,            # TileTuneResult | None
    task: str = "knot",
    extra: dict | None = None,
) -> dict:
    """Assemble the artifact dict from tuner outputs (all optional)."""
    art = {
        "kind": ARTIFACT_KIND,
        "version": ARTIFACT_VERSION,
        "task": task,
        "seed": None if search is None else int(search.seed),
        "space_hash": None if search is None else search.space_hash,
        "calibration": None if search is None else search.calibration,
        "objectives": [] if search is None else list(search.objectives),
        "front": [] if search is None else [p.to_dict() for p in search.front],
        "baseline": None if search is None or search.baseline is None
        else search.baseline.to_dict(),
        "chosen": None if chosen is None else {
            "config": chosen.candidate.to_dict(),
            "metrics": {k: float(v) for k, v in chosen.metrics.items()},
        },
        "tile_plan": None if tile is None else tile.to_dict(),
    }
    if extra:
        art.update(extra)
    return art


def save_tuning_artifact(path: str, artifact: dict) -> None:
    if artifact.get("kind") != ARTIFACT_KIND:
        raise ValueError(f"not a tuning artifact: kind={artifact.get('kind')!r}")
    with open(path, "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)
        f.write("\n")


def load_tuning_artifact(path: str) -> dict:
    with open(path) as f:
        art = json.load(f)
    if art.get("kind") != ARTIFACT_KIND:
        raise ValueError(f"{path}: not a tuning artifact "
                         f"(kind={art.get('kind')!r})")
    if int(art.get("version", -1)) > ARTIFACT_VERSION:
        raise ValueError(
            f"{path}: artifact version {art['version']} is newer than this "
            f"runtime understands ({ARTIFACT_VERSION})"
        )
    return art


def apply_tuning_artifact(artifact: dict, *,
                          register_tiles: bool = True) -> dict:
    """Install the artifact and resolve it into live objects.

    Returns::

        {
          "candidate": Candidate | None,      # the chosen operating point
          "spec": ASPQuantSpec | None,        # its quantization grid
          "input_gen": TMDVConfig | None,     # its TM-DV split
          "cim": CIMConfig | None,            # its ACIM macro config
          "tile_overrides": tuple | None,     # what was registered
          "tile_geometry": (dims, specs, residual_raw) | None,
          "plan": PipelinePlan | None,        # resolved at the artifact's
        }                                     #  bucket, post-registration

    With ``register_tiles`` the tile plan is registered in the runtime plan
    cache (geometry-keyed), so any consumer deploying the matching network
    picks it up transparently; ``plan`` is the cache's resolved plan for
    the artifact's own bucket — under the same seed it is identical to the
    plan the tuner chose (the round-trip the tests assert).
    """
    resolved: dict = {
        "candidate": None, "spec": None, "input_gen": None, "cim": None,
        "tile_overrides": None, "tile_geometry": None, "plan": None,
    }
    chosen = artifact.get("chosen")
    if chosen and chosen.get("config"):
        cand = candidate_from_dict(chosen["config"])
        resolved["candidate"] = cand
        resolved["spec"] = cand.spec()
        # resolve at the calibration the artifact's accuracies were scored
        # under (falling back to the shipped 22nm defaults for artifacts
        # that predate the field)
        cal = artifact.get("calibration") or {}
        ig = cand.input_gen(
            sigma_v_ref=float(cal.get("sigma_v_ref", 0.015)),
            sigma_t=float(cal.get("sigma_t", 0.08)),
        )
        resolved["input_gen"] = ig
        resolved["cim"] = dataclasses.replace(
            cand.cim_config(
                ir_gamma=float(cal.get("ir_gamma", 0.06)),
                sigma_ps_ref=float(cal.get("sigma_ps_ref", 0.05)),
            ),
            input_gen=ig,
        )

    tp = artifact.get("tile_plan")
    if tp:
        dims = tuple(tp["dims"])
        specs = tuple(_spec_from_dict(d) for d in tp["specs"])
        residual_raw = bool(tp["residual_raw"])
        overrides = tp.get("overrides")
        overrides = None if overrides is None else tuple(
            tuple(int(v) for v in t) for t in overrides
        )
        resolved["tile_geometry"] = (dims, specs, residual_raw)
        resolved["tile_overrides"] = overrides
        if register_tiles:
            PLAN_CACHE.set_tile_overrides(dims, specs, residual_raw,
                                          overrides)
            resolved["plan"] = PLAN_CACHE.plan(
                int(tp.get("bucket", 8)), dims, specs,
                residual_raw=residual_raw,
            )
    return resolved
