"""whisper-base — enc-dec; conv frontend stubbed to frame embeddings [arXiv:2212.04356].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import WHISPER_BASE as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
