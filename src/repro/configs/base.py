"""Model configuration schema shared by all assigned architectures.

One frozen dataclass describes every family (dense / audio enc-dec / hybrid
RG-LRU / SSM / MoE / VLM).  ``attn_pattern`` gives the repeating per-layer
block structure; ``num_layers`` is the TOTAL layer count (the pattern is
tiled and truncated, so e.g. recurrentgemma's 38 = 12x(R,R,A)+ (R,R)).

The paper's technique enters through ``ffn_kind="kan"`` (KAN-FFN with
ASP-KAN-HAQ quantization available on every KAN layer) — assigned configs
keep their published FFN so the dry-run matches public literature, and each
config exposes a ``.kan_variant()`` for the paper-technique cells.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|audio|hybrid|ssm|moe|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention structure
    attn_pattern: tuple = ("global",)  # layer kinds: global|local|rglru|ssm
    window_size: int = 4096            # for "local" layers
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # Pad physical head counts up to a multiple of the TP axis (Megatron-style
    # deployment padding).  Logical arch is unchanged: padded wo rows start at
    # zero.  Without this, archs whose head count doesn't divide the TP axis
    # (qwen/phi3: 40 heads on 16-way TP) leave ALL attention weights
    # replicated and XLA all-gathers batch activations to form weight grads —
    # a measured ~28x step-cost blowup (EXPERIMENTS.md §Perf).
    head_pad_multiple: int = 0
    kv_pad_multiple: int = -1          # -1 -> follow head_pad_multiple; 0 -> no pad

    # --- ffn
    ffn_kind: str = "swiglu"           # swiglu|gelu|kan|none
    # --- moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "cumsum"       # cumsum|sort (see §Perf: E-regime dependent)
    # --- ssm (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- rglru (recurrentgemma)
    rnn_width: int = 0                 # 0 -> d_model
    # --- kan ffn (the paper's technique)
    kan_grid: int = 8
    kan_order: int = 3
    kan_n_bits: int = 8
    kan_layer_bits: tuple = ()         # per-layer override of kan_n_bits:
                                       # one width per KANLinear half (mixed
                                       # precision; () -> uniform kan_n_bits)
    kan_d_hidden: int = 0              # 0 -> d_ff // (kan_grid + kan_order)
    # --- encoder-decoder (whisper)
    encoder_layers: int = 0
    enc_seq: int = 1500                # stub frame-embedding length (30 s)
    # --- vlm (pixtral)
    num_patches: int = 0               # stub patch-embedding length
    patch_embed_dim: int = 1024        # ViT output dim before projection

    # --- numerics / compilation
    norm_eps: float = 1e-6
    post_norms: bool = False           # gemma2-style post-layer norms
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # --- distribution / training defaults (overridable per run)
    seq_shard_acts: bool = False       # Megatron-SP: residual stream sharded
                                       # over ("model") on the sequence dim
    microbatch: int = 0                # 0 -> no gradient accumulation
    optimizer: str = "adamw"           # adamw|adafactor|sgdm
    learning_rate: float = 3e-4

    def kan_variant(self, grid: int | None = None) -> "ModelConfig":
        """The paper-technique variant: FFN replaced by a quantizable KAN.

        The KAN hidden width is d_ff/(G+K) rounded UP to a multiple of 128 so
        it stays shardable on a 16-way TP axis — without this the dominant
        spline matmul is replicated on every device (measured 16x flops waste,
        EXPERIMENTS.md §Perf cell 3)."""
        g = grid if grid is not None else self.kan_grid
        nb = g + self.kan_order
        hidden = max(128, -(-(self.d_ff // max(nb, 1)) // 128) * 128) \
            if self.d_ff else 0
        return dataclasses.replace(
            self, name=self.name + "-kanffn", ffn_kind="kan",
            kan_grid=g, kan_d_hidden=hidden,
        )

    @property
    def phys_heads(self) -> int:
        m = self.head_pad_multiple
        if m and self.num_heads % m:
            return self.num_heads + m - self.num_heads % m
        return self.num_heads

    @property
    def phys_kv_heads(self) -> int:
        m = self.head_pad_multiple if self.kv_pad_multiple < 0 \
            else self.kv_pad_multiple
        if m and self.num_kv_heads % m:
            return self.num_kv_heads + m - self.num_kv_heads % m
        return self.num_kv_heads

    @property
    def layer_kinds(self) -> tuple:
        """Per-layer kind for all num_layers, tiling attn_pattern."""
        p = self.attn_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def supports_long_context(self) -> bool:
        """True if no layer's state grows quadratically/unboundedly enough to
        forbid the 500k decode cell (pure full-attention archs are skipped)."""
        kinds = set(self.layer_kinds)
        return "global" not in kinds or self.family in ("hybrid",)
