"""Assigned architectures (public-literature configs) + the paper's own KAN.

Every entry is exactly the assignment table; sources in brackets.  Reduced
("smoke") variants shrink depth/width/experts/vocab for CPU tests while
keeping the family structure (pattern, MoE top-k, SSD state, etc.).
"""

from __future__ import annotations

import dataclasses

from .base import ModelConfig

# --- dense -------------------------------------------------------------------

LLAMA3_405B = ModelConfig(  # [arXiv:2407.21783]
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    head_dim=128, d_ff=53248, vocab_size=128256,
    attn_pattern=("global",), rope_theta=500000.0,
    optimizer="adafactor", microbatch=16,
)

PHI3_MEDIUM = ModelConfig(  # [arXiv:2404.14219]
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    head_dim=128, d_ff=17920, vocab_size=100352,
    attn_pattern=("global",), microbatch=8,
    head_pad_multiple=16,  # 40q/10kv heads -> 48/16 physical (16-way TP);
                           # kv pad 12 was tried for a smaller decode cache but
                           # 12 is not TP-divisible -> replicated kv weights
                           # regress train (43 s memory term) — §Perf
)

GEMMA2_27B = ModelConfig(  # [arXiv:2408.00118; hf]
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
    head_dim=128, d_ff=36864, vocab_size=256000,
    attn_pattern=("local", "global"), window_size=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    ffn_kind="gelu", post_norms=True, tie_embeddings=True,
    microbatch=8,  # peak 18.5 -> <16 GiB/dev
)

QWEN25_14B = ModelConfig(  # [hf:Qwen/Qwen2.5-*]
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=13824, vocab_size=152064,
    attn_pattern=("global",), qkv_bias=True, rope_theta=1000000.0,
    microbatch=8,  # saved-residual footprint: 25.4 -> 13.4 GiB/dev (§Perf)
    head_pad_multiple=16,  # 40q heads -> 48 physical (16-way TP)
    kv_pad_multiple=0,     # 48/8 GQA groups stay integral; halves decode KV
)

# --- audio enc-dec -----------------------------------------------------------

WHISPER_BASE = ModelConfig(  # [arXiv:2212.04356]
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    head_dim=64, d_ff=2048, vocab_size=51865,
    attn_pattern=("global",), encoder_layers=6, enc_seq=1500,
    ffn_kind="gelu",
    microbatch=4,  # peak 64.7 -> ~16 GiB/dev
)

# --- hybrid ------------------------------------------------------------------

RECURRENTGEMMA_9B = ModelConfig(  # [arXiv:2402.19427]
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000,
    attn_pattern=("rglru", "rglru", "local"), window_size=2048,
    rnn_width=4096, ffn_kind="gelu", tie_embeddings=True, microbatch=4,
)

# --- ssm ---------------------------------------------------------------------

MAMBA2_370M = ModelConfig(  # [arXiv:2405.21060]
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    head_dim=0, d_ff=0, vocab_size=50280,
    attn_pattern=("ssm",), ffn_kind="none",
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    tie_embeddings=True,
    microbatch=8,  # SSD chunk matrices: 74 -> 8.6 GiB/dev peak (§Perf)
)

# --- moe ---------------------------------------------------------------------

MIXTRAL_8X7B = ModelConfig(  # [arXiv:2401.04088]
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    attn_pattern=("local",), window_size=4096,
    num_experts=8, num_experts_per_tok=2, moe_dispatch="sort",
    microbatch=16,  # peak 33.2 -> <16 GiB/dev
)

OLMOE_1B_7B = ModelConfig(  # [arXiv:2409.02060]
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    head_dim=128, d_ff=1024, vocab_size=50304,
    attn_pattern=("global",), num_experts=64, num_experts_per_tok=8,
    microbatch=16,  # peak 27.8 -> 11.9 GiB/dev (§Perf, with cumsum dispatch)
)

# --- vlm ---------------------------------------------------------------------

PIXTRAL_12B = ModelConfig(  # [hf:mistralai/Pixtral-12B-2409]
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    attn_pattern=("global",), rope_theta=1000000.0,
    num_patches=256, patch_embed_dim=1024,
    microbatch=8,  # peak 22.5 -> ~12 GiB/dev
)

# --- the paper's own application (edge KAN, knot theory) ---------------------
# Not an LM; lives in core/kan_layer + benchmarks.  Exposed here so
# --arch kan-knot selects the fig13 pipeline.

KAN_KNOT = {"name": "kan-knot", "dims": (17, 1, 14), "g_kan1": 5, "g_kan2": 68}


ARCHS = {
    c.name: c
    for c in [
        LLAMA3_405B, PHI3_MEDIUM, GEMMA2_27B, QWEN25_14B, WHISPER_BASE,
        RECURRENTGEMMA_9B, MAMBA2_370M, MIXTRAL_8X7B, OLMOE_1B_7B, PIXTRAL_12B,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-kanffn"):
        return ARCHS[name[: -len("-kanffn")]].kan_variant()
    return ARCHS[name]


# ----------------------------------------------------------------------------
# Reduced configs for CPU smoke tests (same family structure, tiny sizes)
# ----------------------------------------------------------------------------


def smoke_config(name: str) -> ModelConfig:
    cfg = get_config(name)
    nl = max(len(cfg.attn_pattern) + 1, 2)  # >= one full pattern + remainder
    upd = dict(
        num_layers=nl,
        d_model=64,
        d_ff=0 if cfg.family == "ssm" else 128,
        vocab_size=256,
        head_dim=16,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=(2 if cfg.num_kv_heads > 1 else 1) if cfg.num_heads else 0,
        window_size=min(cfg.window_size, 32),
        rnn_width=64 if cfg.rnn_width else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        num_experts=4 if cfg.num_experts else 0,
        num_experts_per_tok=min(2, cfg.num_experts_per_tok),
        encoder_layers=2 if cfg.encoder_layers else 0,
        enc_seq=24 if cfg.encoder_layers else 1500,
        num_patches=8 if cfg.num_patches else 0,
        patch_embed_dim=32 if cfg.num_patches else 1024,
        kan_d_hidden=16 if cfg.ffn_kind == "kan" else 0,
        head_pad_multiple=0,
        kv_pad_multiple=-1,
        microbatch=0,
        dtype="float32",
        remat=False,
    )
    return dataclasses.replace(cfg, **upd)


# The four shapes assigned to the LM family
SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}

# long_500k runs only for sub-quadratic-state archs (see DESIGN.md):
LONG_OK = {"gemma2-27b", "recurrentgemma-9b", "mamba2-370m", "mixtral-8x7b"}


def cells():
    """All live (arch, shape) dry-run cells."""
    out = []
    for name in ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and name not in LONG_OK:
                continue
            out.append((name, shape))
    return out
