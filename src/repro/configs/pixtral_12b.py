"""pixtral-12b — ViT patch stub + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import PIXTRAL_12B as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
