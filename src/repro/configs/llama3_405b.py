"""llama3-405b — dense GQA, 128k vocab [arXiv:2407.21783].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import LLAMA3_405B as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
