"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import OLMOE_1B_7B as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
