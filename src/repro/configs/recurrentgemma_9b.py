"""recurrentgemma-9b — RG-LRU + local attention 2:1 [arXiv:2402.19427].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import RECURRENTGEMMA_9B as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
