"""mamba2-370m — SSD (state-space duality), attention-free [arXiv:2405.21060].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import MAMBA2_370M as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
