"""qwen2.5-14b — GQA with QKV bias [hf:Qwen/Qwen2.5-*].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import QWEN25_14B as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
