"""gemma2-27b — local+global alternating, logit softcaps [arXiv:2408.00118; hf].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import GEMMA2_27B as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
