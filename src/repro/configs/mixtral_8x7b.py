"""mixtral-8x7b — 8 experts top-2, sliding-window attention [arXiv:2401.04088].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import MIXTRAL_8X7B as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
