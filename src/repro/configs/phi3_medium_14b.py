"""phi3-medium-14b — RoPE SwiGLU GQA [arXiv:2404.14219].

Exact assigned config; see registry.py for the literal numbers and
smoke_config() for the reduced CPU-test variant.
"""

from .registry import PHI3_MEDIUM as CONFIG
from .registry import smoke_config

SMOKE = smoke_config(CONFIG.name)
