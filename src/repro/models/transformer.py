"""Decoder-only / encoder-decoder transformer stack over mixed layer kinds.

Layers are organized into REPEATING BLOCKS given by cfg.attn_pattern (e.g.
gemma2: ("local","global"); recurrentgemma: ("rglru","rglru","local");
mamba2: ("ssm",)).  Params for each group are STACKED over repeats so the
stack runs under jax.lax.scan with one compiled block body — essential to
keep HLO size flat in depth for the 126-layer dry-runs — with an unrolled
remainder group when num_layers % len(pattern) != 0.

Remat: each scanned block body is wrapped in jax.checkpoint when cfg.remat.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L

Params = Any


# ----------------------------------------------------------------------------
# Group structure
# ----------------------------------------------------------------------------


def layer_groups(cfg: ModelConfig):
    """[(kinds_tuple, repeats)] — one scanned group + optional remainder."""
    period = len(cfg.attn_pattern)
    full, rem = divmod(cfg.num_layers, period)
    groups = []
    if full:
        groups.append((tuple(cfg.attn_pattern), full))
    if rem:
        groups.append((tuple(cfg.attn_pattern[:rem]), 1))
    return groups


def _init_block(key, cfg: ModelConfig, kinds, cross: bool):
    """One block = len(kinds) layers; returns params dict keyed l{i}_*."""
    p = {}
    for i, kind in enumerate(kinds):
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        if kind in ("global", "local", "bidir"):
            p[f"l{i}_attn"] = L.init_attention(k1, cfg)
            p[f"l{i}_ln1"] = L.init_rmsnorm(cfg.d_model)
        elif kind == "rglru":
            p[f"l{i}_rnn"] = L.init_rglru(k1, cfg)
            p[f"l{i}_ln1"] = L.init_rmsnorm(cfg.d_model)
        elif kind == "ssm":
            p[f"l{i}_ssm"] = L.init_mamba2(k1, cfg)
            p[f"l{i}_ln1"] = L.init_rmsnorm(cfg.d_model)
        else:
            raise ValueError(kind)
        if cross and kind != "ssm":
            p[f"l{i}_xattn"] = L.init_attention(k2, cfg, cross=True)
            p[f"l{i}_lnx"] = L.init_rmsnorm(cfg.d_model)
        if kind != "ssm" and cfg.ffn_kind != "none":
            if cfg.num_experts > 0:
                p[f"l{i}_moe"] = L.init_moe(k3, cfg)
            else:
                p[f"l{i}_ffn"] = L.init_ffn(k3, cfg)
            p[f"l{i}_ln2"] = L.init_rmsnorm(cfg.d_model)
        if cfg.post_norms:
            p[f"l{i}_pn1"] = L.init_rmsnorm(cfg.d_model)
            if kind != "ssm" and cfg.ffn_kind != "none":
                p[f"l{i}_pn2"] = L.init_rmsnorm(cfg.d_model)
    return p


def init_stack(key, cfg: ModelConfig, cross: bool = False):
    """Stacked params per group (leading dim = repeats).  Group structure
    (kinds, repeats) is STATIC — recomputed from cfg via layer_groups(), never
    stored in the pytree (params must stay a pure array tree for jit)."""
    groups = []
    for kinds, repeats in layer_groups(cfg):
        keys = jax.random.split(key, repeats + 1)
        key = keys[0]
        blocks = [_init_block(k, cfg, kinds, cross) for k in keys[1:]]
        groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *blocks))
    return groups


# ----------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ----------------------------------------------------------------------------


def _block_fwd(bp, x, cfg: ModelConfig, kinds, positions, enc_out):
    x = L.constrain_act(x)
    for i, kind in enumerate(kinds):
        h = L.rmsnorm(bp[f"l{i}_ln1"], x, cfg.norm_eps)
        if kind in ("global", "local", "bidir"):
            h = L.attention(bp[f"l{i}_attn"], h, cfg, kind, positions)
        elif kind == "rglru":
            h, _ = L.rglru(bp[f"l{i}_rnn"], h, cfg)
        elif kind == "ssm":
            h, _ = L.mamba2(bp[f"l{i}_ssm"], h, cfg)
        if cfg.post_norms:
            h = L.rmsnorm(bp[f"l{i}_pn1"], h, cfg.norm_eps)
        x = L.constrain_act(x + h)
        if f"l{i}_xattn" in bp:
            h = L.rmsnorm(bp[f"l{i}_lnx"], x, cfg.norm_eps)
            h = L.attention(bp[f"l{i}_xattn"], h, cfg, "cross", enc_out=enc_out)
            x = x + h
        if f"l{i}_ffn" in bp or f"l{i}_moe" in bp:
            h = L.rmsnorm(bp[f"l{i}_ln2"], x, cfg.norm_eps)
            if f"l{i}_moe" in bp:
                h = L.moe(bp[f"l{i}_moe"], h, cfg)
            else:
                h = L.ffn(bp[f"l{i}_ffn"], h, cfg)
            if cfg.post_norms:
                h = L.rmsnorm(bp[f"l{i}_pn2"], h, cfg.norm_eps)
            x = L.constrain_act(x + h)
    return x


def stack_forward(groups, x, cfg: ModelConfig, positions=None, enc_out=None):
    for gp, (kinds, repeats) in zip(groups, layer_groups(cfg)):
        body = functools.partial(
            _block_fwd, cfg=cfg, kinds=kinds, positions=positions, enc_out=enc_out
        )
        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers and repeats > 1:
            def scan_body(carry, bp):
                return body(bp, carry), None

            x, _ = jax.lax.scan(scan_body, x, gp)
        else:
            for r in range(repeats):
                bp = jax.tree.map(lambda a: a[r], gp)
                x = body(bp, x)
    return x


# ----------------------------------------------------------------------------
# Decode (single token) with per-group stacked caches
# ----------------------------------------------------------------------------


def init_stack_cache(cfg: ModelConfig, groups, batch: int, max_len: int,
                     enc_len: int = 0):
    """Cache pytree mirroring the group structure (leading dim = repeats)."""
    caches = []
    del groups  # structure comes from cfg
    for kinds, repeats in layer_groups(cfg):
        one = {}
        for i, kind in enumerate(kinds):
            if kind in ("global", "local", "bidir"):
                one[f"l{i}_kv"] = L.init_kv_cache(cfg, batch, max_len, kind)
            elif kind == "rglru":
                one[f"l{i}_rnn"] = L.init_rglru_state(cfg, batch)
            elif kind == "ssm":
                one[f"l{i}_ssm"] = L.init_mamba2_state(cfg, batch)
            if enc_len > 0 and kind != "ssm":  # cross-attention K/V
                one[f"l{i}_xkv"] = {
                    "k": jnp.zeros((batch, enc_len, cfg.phys_kv_heads,
                                    cfg.head_dim), jnp.dtype(cfg.dtype)),
                    "v": jnp.zeros((batch, enc_len, cfg.phys_kv_heads,
                                    cfg.head_dim), jnp.dtype(cfg.dtype)),
                }
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (repeats,) + a.shape).copy(), one
        )
        caches.append(stacked)
    return caches


def init_stack_cache_paged(cfg: ModelConfig, groups, num_blocks: int,
                           block_size: int):
    """Paged-pool cache pytree: each l{i}_kv leaf is (repeats, NB, bs, H, D).

    Requests address the shared pool through per-slot block tables
    (:mod:`repro.serve.kvpool`); only pure global-attention decoders page
    (rolling-window / recurrent / cross state has no block structure)."""
    caches = []
    del groups  # structure comes from cfg
    for kinds, repeats in layer_groups(cfg):
        one = {}
        for i, kind in enumerate(kinds):
            if kind != "global":
                raise ValueError(
                    f"paged KV cache requires a pure global-attention "
                    f"decoder; layer kind {kind!r} is not pageable"
                )
            one[f"l{i}_kv"] = L.init_paged_kv_cache(cfg, num_blocks,
                                                    block_size)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (repeats,) + a.shape).copy(), one
        )
        caches.append(stacked)
    return caches


def _block_decode(bp, cache, x, pos, cfg: ModelConfig, kinds,
                  block_table=None):
    new_cache = dict(cache)
    for i, kind in enumerate(kinds):
        h = L.rmsnorm(bp[f"l{i}_ln1"], x, cfg.norm_eps)
        if kind in ("global", "local", "bidir"):
            h, new_cache[f"l{i}_kv"] = L.attention_decode(
                bp[f"l{i}_attn"], h, cache[f"l{i}_kv"], pos, cfg,
                "local" if kind == "local" else "global",
                block_table=block_table,
            )
        elif kind == "rglru":
            h, new_cache[f"l{i}_rnn"] = L.rglru(
                bp[f"l{i}_rnn"], h, cfg, state=cache[f"l{i}_rnn"]
            )
        elif kind == "ssm":
            h, new_cache[f"l{i}_ssm"] = L.mamba2(
                bp[f"l{i}_ssm"], h, cfg, state=cache[f"l{i}_ssm"]
            )
        if cfg.post_norms:
            h = L.rmsnorm(bp[f"l{i}_pn1"], h, cfg.norm_eps)
        x = x + h
        if f"l{i}_xattn" in bp:
            h = L.rmsnorm(bp[f"l{i}_lnx"], x, cfg.norm_eps)
            h, _ = L.attention_decode(
                bp[f"l{i}_xattn"], h, cache[f"l{i}_xkv"], pos, cfg, "cross"
            )
            x = x + h
        if f"l{i}_ffn" in bp or f"l{i}_moe" in bp:
            h = L.rmsnorm(bp[f"l{i}_ln2"], x, cfg.norm_eps)
            if f"l{i}_moe" in bp:
                h = L.moe(bp[f"l{i}_moe"], h, cfg)
            else:
                h = L.ffn(bp[f"l{i}_ffn"], h, cfg)
            if cfg.post_norms:
                h = L.rmsnorm(bp[f"l{i}_pn2"], h, cfg.norm_eps)
            x = x + h
    return x, new_cache


def stack_decode(groups, caches, x, pos, cfg: ModelConfig, block_table=None):
    new_caches = []
    for gp, cache, (kinds, repeats) in zip(groups, caches, layer_groups(cfg)):
        body = functools.partial(_block_decode, cfg=cfg, kinds=kinds,
                                 block_table=block_table)
        if cfg.scan_layers and repeats > 1:
            def scan_body(carry, inp):
                bp, c = inp
                y, nc = body(bp, c, carry, pos)
                return y, nc

            x, nc = jax.lax.scan(scan_body, x, (gp, cache))
            new_caches.append(nc)
        else:
            ncs = []
            for r in range(repeats):
                bp = jax.tree.map(lambda a: a[r], gp)
                c = jax.tree.map(lambda a: a[r], cache)
                x, nc = body(bp, c, x, pos)
                ncs.append(nc)
            new_caches.append(jax.tree.map(lambda *xs: jnp.stack(xs), *ncs))
    return x, new_caches


# ----------------------------------------------------------------------------
# Prefill: full-sequence forward that ALSO materializes the KV caches
# ----------------------------------------------------------------------------


def _block_prefill(bp, cache, x, cfg: ModelConfig, kinds, positions, enc_out):
    """Run a block over the whole prompt, filling caches."""
    new_cache = dict(cache)
    x = L.constrain_act(x)
    b, s, _ = x.shape
    for i, kind in enumerate(kinds):
        h = L.rmsnorm(bp[f"l{i}_ln1"], x, cfg.norm_eps)
        if kind in ("global", "local", "bidir"):
            akind = kind if kind != "bidir" else "bidir"
            q, k, v = L._qkv(bp[f"l{i}_attn"], h, cfg, kind != "bidir", positions)
            kv = cache[f"l{i}_kv"]
            t = kv["k"].shape[1]
            if kind == "local" and t < s:
                # rolling window: keep the last `t` positions
                ck = jax.lax.dynamic_slice_in_dim(k, s - t, t, axis=1)
                cv = jax.lax.dynamic_slice_in_dim(v, s - t, t, axis=1)
                # roll so that slot = pos % window
                shift = (s - t) % t
                ck = jnp.roll(ck, shift, axis=1)
                cv = jnp.roll(cv, shift, axis=1)
            else:
                ck = kv["k"].at[:, :s].set(k.astype(kv["k"].dtype))
                cv = kv["v"].at[:, :s].set(v.astype(kv["v"].dtype))
            new_cache[f"l{i}_kv"] = {"k": ck, "v": cv}
            h = L._sdpa(q, k, v, cfg, kind)
            h = jnp.einsum("bshk,hkd->bsd", h, bp[f"l{i}_attn"]["wo"])
        elif kind == "rglru":
            h, st = L.rglru_prefill(bp[f"l{i}_rnn"], h, cfg)
            new_cache[f"l{i}_rnn"] = st
        elif kind == "ssm":
            h, st = L.mamba2_prefill(bp[f"l{i}_ssm"], h, cfg)
            new_cache[f"l{i}_ssm"] = st
        if cfg.post_norms:
            h = L.rmsnorm(bp[f"l{i}_pn1"], h, cfg.norm_eps)
        x = L.constrain_act(x + h)
        if f"l{i}_xattn" in bp:
            h = L.rmsnorm(bp[f"l{i}_lnx"], x, cfg.norm_eps)
            xp = bp[f"l{i}_xattn"]
            xk = jnp.einsum("bsd,dhk->bshk", enc_out, xp["wk"])
            xv = jnp.einsum("bsd,dhk->bshk", enc_out, xp["wv"])
            new_cache[f"l{i}_xkv"] = {"k": xk.astype(x.dtype), "v": xv.astype(x.dtype)}
            h = L.attention(xp, h, cfg, "cross", enc_out=enc_out)
            x = x + h
        if f"l{i}_ffn" in bp or f"l{i}_moe" in bp:
            h = L.rmsnorm(bp[f"l{i}_ln2"], x, cfg.norm_eps)
            if f"l{i}_moe" in bp:
                h = L.moe(bp[f"l{i}_moe"], h, cfg)
            else:
                h = L.ffn(bp[f"l{i}_ffn"], h, cfg)
            if cfg.post_norms:
                h = L.rmsnorm(bp[f"l{i}_pn2"], h, cfg.norm_eps)
            x = x + h
    return x, new_cache


def _block_prefill_paged(bp, cache, x, cfg: ModelConfig, kinds, positions,
                         block_table, start, real_end):
    """One block over a B=1 PREFILL CHUNK against the paged KV pool.

    x: (1, C, D) chunk activations at absolute positions
    ``start + arange(C)``; chunk K/V scatter into the request's pool blocks
    (pad rows >= real_end are dropped) and attention runs against the FULL
    gathered view, so chunk queries see the cached prefix + earlier chunks
    + themselves under the ordinary causal mask — stale tail lanes mask to
    exact zeros.  Only "global" layers are pageable (init_stack_cache_paged
    enforces it)."""
    new_cache = dict(cache)
    x = L.constrain_act(x)
    for i, kind in enumerate(kinds):
        h = L.rmsnorm(bp[f"l{i}_ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(bp[f"l{i}_attn"], h, cfg, True, positions)
        new_cache[f"l{i}_kv"], gk, gv = L.paged_prefill_update(
            cache[f"l{i}_kv"], k, v, block_table, start, real_end
        )
        t = gk.shape[1]
        h = L._sdpa(q, gk, gv, cfg, "global",
                    qpos=positions[0], kpos=jnp.arange(t))
        h = jnp.einsum("bshk,hkd->bsd", h, bp[f"l{i}_attn"]["wo"])
        if cfg.post_norms:
            h = L.rmsnorm(bp[f"l{i}_pn1"], h, cfg.norm_eps)
        x = L.constrain_act(x + h)
        if f"l{i}_ffn" in bp or f"l{i}_moe" in bp:
            h = L.rmsnorm(bp[f"l{i}_ln2"], x, cfg.norm_eps)
            if f"l{i}_moe" in bp:
                h = L.moe(bp[f"l{i}_moe"], h, cfg)
            else:
                h = L.ffn(bp[f"l{i}_ffn"], h, cfg)
            if cfg.post_norms:
                h = L.rmsnorm(bp[f"l{i}_pn2"], h, cfg.norm_eps)
            x = L.constrain_act(x + h)
    return x, new_cache


def stack_prefill_paged(groups, caches, x, cfg: ModelConfig, block_table,
                        start, real_end, positions):
    """Chunked prefill over the paged pool; mirrors :func:`stack_prefill`
    (scan over stacked repeats) with the paged block body."""
    new_caches = []
    for gp, cache, (kinds, repeats) in zip(groups, caches, layer_groups(cfg)):
        body = functools.partial(
            _block_prefill_paged, cfg=cfg, kinds=kinds, positions=positions,
            block_table=block_table, start=start, real_end=real_end,
        )
        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers and repeats > 1:
            def scan_body(carry, inp):
                bp, c = inp
                y, nc = body(bp, c, carry)
                return y, nc

            x, nc = jax.lax.scan(scan_body, x, (gp, cache))
            new_caches.append(nc)
        else:
            ncs = []
            for r in range(repeats):
                bp = jax.tree.map(lambda a: a[r], gp)
                c = jax.tree.map(lambda a: a[r], cache)
                x, nc = body(bp, c, x)
                ncs.append(nc)
            new_caches.append(jax.tree.map(lambda *xs: jnp.stack(xs), *ncs))
    return x, new_caches


def stack_prefill(groups, caches, x, cfg: ModelConfig, positions=None,
                  enc_out=None):
    new_caches = []
    for gp, cache, (kinds, repeats) in zip(groups, caches, layer_groups(cfg)):
        body = functools.partial(
            _block_prefill, cfg=cfg, kinds=kinds, positions=positions,
            enc_out=enc_out,
        )
        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers and repeats > 1:
            def scan_body(carry, inp):
                bp, c = inp
                y, nc = body(bp, c, carry)
                return y, nc

            x, nc = jax.lax.scan(scan_body, x, (gp, cache))
            new_caches.append(nc)
        else:
            ncs = []
            for r in range(repeats):
                bp = jax.tree.map(lambda a: a[r], gp)
                c = jax.tree.map(lambda a: a[r], cache)
                x, nc = body(bp, c, x)
                ncs.append(nc)
            new_caches.append(jax.tree.map(lambda *xs: jnp.stack(xs), *ncs))
    return x, new_caches
