"""Transformer substrate layers: norms, RoPE, GQA attention (causal /
sliding-window / bidirectional / cross), SwiGLU & GeLU & KAN FFN, top-k MoE,
RG-LRU, Mamba-2 SSD.

Conventions
-----------
* Params are plain nested dicts of jnp arrays; init fns take (key, cfg) and
  are shape-deterministic (usable under jax.eval_shape for the dry-run).
* Activations: (B, S, D) in cfg dtype; reductions/softmax in float32.
* Every layer has a full-sequence path (train/prefill) and a single-step
  decode path with an explicit state/cache pytree.
* The KAN-FFN is the paper's technique as a first-class LM layer: each of
  the two projections is a KANLinear (B-spline edges); its quantized
  deployment path reuses core.asp_quant / kernels.kan_spline.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.asp_quant import ASPQuantSpec
from ..core.bspline import bspline_basis, bspline_basis_fast

Params = Any

# ----------------------------------------------------------------------------
# misc
# ----------------------------------------------------------------------------


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# Activation-sharding constraint applied at residual-stream boundaries.
# The launcher installs a NamedSharding for (B, S, D) activations; without it
# XLA may resolve the FSDP-weight (contracting-dim over "data") vs
# batch-over-"data" conflict by ALL-GATHERING THE BATCH — a measured 16x
# compute/memory blowup (EXPERIMENTS.md §Perf, qwen train iteration 3).
_ACT_SPEC = None


def set_activation_spec(spec):
    """spec: NamedSharding/PartitionSpec for (batch, seq, d_model), or None."""
    global _ACT_SPEC
    _ACT_SPEC = spec


def constrain_act(x):
    if _ACT_SPEC is None or x.ndim < 2:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    except Exception:
        return x


def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(x.dtype)


def softcap(x, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(x / cap) * cap
    return x


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------------
# Attention (GQA; causal / local / bidirectional / cross; KV cache)
# ----------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    """Physical head counts may be PADDED to a TP multiple (cfg.phys_heads).

    Padded wo rows start at zero so the logical function is exactly the
    published architecture at init; padding is a deployment layout choice
    (see configs/base.py head_pad_multiple)."""
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.phys_heads, cfg.phys_kv_heads
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    sc = 1.0 / math.sqrt(d)
    wo = jax.random.normal(ks[3], (hq, hd, d), dt) * sc
    if hq != cfg.num_heads:  # zero the padded heads' output rows
        mask = (jnp.arange(hq) < cfg.num_heads).astype(dt)[:, None, None]
        wo = wo * mask
    p = {
        "wq": jax.random.normal(ks[0], (d, hq, hd), dt) * sc,
        "wk": jax.random.normal(ks[1], (d, hkv, hd), dt) * sc,
        "wv": jax.random.normal(ks[2], (d, hkv, hd), dt) * sc,
        "wo": wo,
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq, hd), dt)
        p["bk"] = jnp.zeros((hkv, hd), dt)
        p["bv"] = jnp.zeros((hkv, hd), dt)
    return p


def _qkv(p, x, cfg, use_rope, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # Block XLA's dot reassociation (x·Wq)·Kᵀ -> x·(Wq·Kᵀ): when the qkv
    # projections are replicated (head count not divisible by the TP axis)
    # the rewrite costs 2·S·D·T flops instead of 2·S·(D+T)·hd — an ~18x
    # compute blowup measured on qwen/phi3 train cells (EXPERIMENTS.md §Perf).
    q, k, v = _grad_safe_barrier((q, k, v))
    return q, k, v


# jax.lax.optimization_barrier has no differentiation rule; the barrier is
# purely a scheduling hint, so its VJP is the identity (with the same barrier
# applied to the cotangents to keep the backward dots un-reassociated too).
@jax.custom_vjp
def _grad_safe_barrier(xs):
    return jax.lax.optimization_barrier(xs)


def _grad_safe_barrier_fwd(xs):
    return _grad_safe_barrier(xs), None


def _grad_safe_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_grad_safe_barrier.defvjp(_grad_safe_barrier_fwd, _grad_safe_barrier_bwd)


ATTN_CHUNK = 1024  # query-chunk size for the memory-bounded attention path


def _masked_softmax(logits, mask):
    """Softmax over the last axis with an explicit validity mask.

    Matches ``jax.nn.softmax`` bit-for-bit whenever a row has at least one
    valid key (the max valid logit contributes exp(0) = 1, so the
    denominator is >= 1 and masked lanes underflow to exactly 0 either
    way); fully-masked rows — e.g. qpos = -1 padding from the remainder
    chunk — produce EXACT zeros instead of a uniform average over -1e30
    garbage."""
    if mask is None:
        return jax.nn.softmax(logits, axis=-1)
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.where(mask, jnp.exp(logits - m), 0.0)
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)


def _sdpa_chunk(qc, qpos, k, v, kpos, cfg: ModelConfig, kind: str):
    """One query chunk.  qc: (B,C,Hkv,G,D); qpos: (C,); k/v: (B,T,Hkv,D);
    kpos: (T,).  Masks are built on the fly from positions — no (S,T)
    tensor is ever materialized (the 32k/500k cells depend on this)."""
    d = qc.shape[-1]
    logits = jnp.einsum(
        "bchgd,bthd->bhgct", qc.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(d)
    logits = softcap(logits, cfg.attn_logit_softcap)
    m = None
    if kind in ("global", "local"):
        m = kpos[None, :] <= qpos[:, None]                    # causal (C,T)
        if kind == "local" and cfg.window_size > 0:
            m &= kpos[None, :] > qpos[:, None] - cfg.window_size
        m = m[None, None, None]
    probs = _masked_softmax(logits, m)
    return jnp.einsum("bhgct,bthd->bchgd", probs.astype(v.dtype), v)


# layers.py attention kinds -> kernels.attention mask kinds
_FLASH_KIND = {"global": "causal", "local": "local",
               "bidir": "full", "cross": "full"}


def _sdpa_flash(q, k, v, cfg: ModelConfig, kind: str, qpos, kpos):
    """The fused Pallas flash-attention path (backend "flash")."""
    from ..kernels.attention import flash_attention

    out = flash_attention(
        q, k, v, kind=_FLASH_KIND[kind], qpos=qpos, kpos=kpos,
        window=cfg.window_size, softcap=cfg.attn_logit_softcap,
    )
    return out.astype(v.dtype)


def _sdpa_ref(q, k, v, cfg: ModelConfig, kind: str, qpos=None, kpos=None):
    """The chunked XLA composition (backend "ref" — the parity oracle).

    Long sequences are processed in ATTN_CHUNK query chunks under lax.scan;
    a non-multiple remainder is PADDED to a full chunk (padded rows carry
    qpos = -1, are fully masked, and provably contribute zeros) instead of
    abandoning the memory-bounded path for the whole sequence."""
    b, s, hq, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    if qpos is None:
        qpos = jnp.arange(s) + (t - s)
    if kpos is None:
        kpos = jnp.arange(t)

    if s <= ATTN_CHUNK:
        out = _sdpa_chunk(q.reshape(b, s, hkv, g, d), qpos, k, v, kpos,
                          cfg, kind)
        return out.reshape(b, s, hq, d)

    pad = (-s) % ATTN_CHUNK
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, (0, pad), constant_values=-1)
    sp = s + pad
    nc = sp // ATTN_CHUNK
    qcs = q.reshape(b, nc, ATTN_CHUNK, hkv, g, d).swapaxes(0, 1)
    qps = qpos.reshape(nc, ATTN_CHUNK)

    def body(_, inp):
        qc, qp = inp
        return None, _sdpa_chunk(qc, qp, k, v, kpos, cfg, kind)

    _, outs = jax.lax.scan(body, None, (qcs, qps))
    out = outs.swapaxes(0, 1).reshape(b, sp, hq, d)
    return out[:, :s]


def _sdpa(q, k, v, cfg: ModelConfig, kind: str, qpos=None, kpos=None,
          backend=None):
    """q: (B,S,Hq,D), k/v: (B,T,Hkv,D).  kind: global|local|bidir|cross.

    Dispatches to the resolved runtime attention backend: "ref" (chunked
    XLA composition) or "flash" (fused Pallas online-softmax kernel) — see
    :mod:`repro.runtime.attention`.  Resolution happens at trace time, so
    jitted callers that want to switch backends must key their compiled
    steps on the resolved name (``ServeEngine`` does)."""
    from ..runtime.attention import resolve_attn_backend

    if resolve_attn_backend(backend) == "flash":
        return _sdpa_flash(q, k, v, cfg, kind, qpos, kpos)
    return _sdpa_ref(q, k, v, cfg, kind, qpos, kpos)


def attention(p, x, cfg: ModelConfig, kind: str, positions=None, enc_out=None):
    """Full-sequence attention. kind: global|local|bidir|cross."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if kind == "cross":
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    else:
        use_rope = kind in ("global", "local")
        q, k, v = _qkv(p, x, cfg, use_rope, positions)
    out = _sdpa(q, k, v, cfg, kind)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _sdpa_batch_masked(q, k, v, mask, cfg: ModelConfig):
    """Decode-path attention with a per-batch key mask.

    mask: (B, T) — one key-validity row shared by every query (the classic
    single-token decode step) — or (B, S, T) — one row per query, as the
    speculative-decode verify pass needs (each of the S verified positions
    has its own causal frontier)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qr = q.reshape(b, s, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bshgd,bthd->bhgst", qr, k.astype(jnp.float32))
    logits = logits / math.sqrt(d)
    logits = softcap(logits, cfg.attn_logit_softcap)
    if mask is None:
        m = None
    elif mask.ndim == 3:
        m = mask[:, None, None, :, :]          # (B,1,1,S,T)
    else:
        m = mask[:, None, None, None, :]       # (B,1,1,1,T)
    probs = _masked_softmax(logits, m)
    out = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, d)


def _sdpa_decode(q, k, v, cfg: ModelConfig, kind: str, qpos, kpos,
                 backend=None):
    """Decode-step attention built from per-batch positions.

    q: (B,S,Hq,D) — S=1 for the classic single-token step, S=k+1 for the
    speculative-decode verify pass; k/v: (B,T,Hkv,D); qpos: (B,S) absolute
    query positions; kpos: (B,T) absolute position held by each cache slot,
    -1 for unwritten slots.  ``qpos``/``kpos`` None means bidirectional
    over the whole cache (cross-attention decode).  Dispatches like
    :func:`_sdpa`: the "ref" backend materializes the mask ((B,T) at S=1 —
    unchanged from the single-token step — or (B,S,T) per query row),
    "flash" hands the positions to the fused kernel, which already builds
    its causal mask per (B,S) query row.  Both mask non-causal AND
    unwritten (kpos < 0) slots; for the rolling-window cache causal +
    validity is the complete window predicate, because the buffer only
    ever holds the last ``window`` positions."""
    from ..runtime.attention import resolve_attn_backend

    if resolve_attn_backend(backend) == "flash":
        # "global" maps to the kernel's causal mask; the local rolling cache
        # needs no window predicate (see above), so it is causal too
        fkind = "bidir" if kind in ("bidir", "cross") else "global"
        return _sdpa_flash(q, k, v, cfg, fkind, qpos, kpos)
    mask = None
    if kind not in ("bidir", "cross"):
        if qpos.shape[1] == 1:
            mask = (kpos >= 0) & (kpos <= qpos)                    # (B,T)
        else:
            mask = ((kpos[:, None, :] >= 0)
                    & (kpos[:, None, :] <= qpos[:, :, None]))      # (B,S,T)
    return _sdpa_batch_masked(q, k, v, mask, cfg)


def attention_decode(p, x, cache, pos, cfg: ModelConfig, kind: str, enc_out=None,
                     block_table=None):
    """Decode-step attention.  x: (B, S, D) — S=1 for the classic
    one-token step, S=k+1 for the speculative-decode verify pass, whose
    tokens occupy consecutive positions pos..pos+S-1; cache: {"k","v"}:
    (B, T, Hkv, D); pos: (B,) int32 current position.  Returns
    (out, new_cache).

    With ``block_table`` ((B, nblk) int32) the cache is the PAGED pool —
    {"k","v"}: (NB, block_size, Hkv, D), no batch dim — and the table maps
    each request's logical block j to pool block id ``block_table[b, j]``.
    The step scatters the new K/V into the owning pool blocks and gathers
    the table into a (B, nblk*block_size, Hkv, D) view, which is exactly
    the contiguous cache's shape and, at every VALID position, its values —
    stale lanes (unwritten tail blocks point at the scratch block, and
    rolled-back speculative rows are rewritten before any query may attend
    them) are masked by the ``kpos <= qpos`` predicate and contribute
    exact zeros (see ``_masked_softmax``), so paged decode is
    bit-identical to contiguous decode.  Positions at or beyond the
    table's coverage are routed to pool id NB and dropped (``mode="drop"``,
    the same idiom as :func:`paged_prefill_update`); the caller caps
    emission before those rows could ever be consumed.  Only "global"
    attention pages (the engine gates on pure-global decoders)."""
    b = x.shape[0]
    s = x.shape[1]
    if kind == "cross":
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k, v = cache["k"], cache["v"]  # precomputed from enc_out
        out = _sdpa_decode(q, k, v, cfg, "cross", None, None)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache

    positions = pos[:, None] + jnp.arange(s)[None, :]  # (B, S)
    q, k, v = _qkv(p, x, cfg, True, positions)
    if block_table is not None:
        bs = cache["k"].shape[1]
        nblk = block_table.shape[1]
        nb = cache["k"].shape[0]
        bidx = jnp.arange(b)
        pb = jnp.clip(positions // bs, 0, nblk - 1)
        blk = jnp.where(positions < nblk * bs,
                        block_table[bidx[:, None], pb], nb)  # (B,S) pool ids
        off = positions % bs
        # retired slots all map to the scratch block; duplicate (blk, off)
        # targets race there, which is harmless — scratch lanes are never
        # unmasked for any live request
        ck = cache["k"].at[blk, off].set(k.astype(cache["k"].dtype),
                                         mode="drop")
        cv = cache["v"].at[blk, off].set(v.astype(cache["v"].dtype),
                                         mode="drop")
        gk = ck[block_table].reshape(b, nblk * bs, *ck.shape[2:])
        gv = cv[block_table].reshape(b, nblk * bs, *cv.shape[2:])
        kpos = jnp.broadcast_to(jnp.arange(nblk * bs)[None, :],
                                (b, nblk * bs))
        out = _sdpa_decode(q, gk, gv, cfg, kind, positions, kpos)
        return (jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
                {"k": ck, "v": cv})
    t = cache["k"].shape[1]
    if kind == "local" and 0 < cfg.window_size <= t:
        # rolling window cache: slot = pos % window (t == window).
        # Single-token only — the serve engine never routes multi-token
        # verify through local layers (paged mode gates on pure-global).
        slot = (pos % t)[:, None]
        ck = _scatter_time(cache["k"], k, slot)
        cv = _scatter_time(cache["v"], v, slot)
        kpos = _window_positions(pos, t, t)  # absolute pos held by each slot
    else:
        ck = _scatter_time(cache["k"], k, positions)
        cv = _scatter_time(cache["v"], v, positions)
        kpos = jnp.broadcast_to(jnp.arange(ck.shape[1])[None, :],
                                (b, ck.shape[1]))
    out = _sdpa_decode(q, ck, cv, cfg, kind, positions, kpos)
    new_cache = {"k": ck, "v": cv}
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def _scatter_time(cache, new, slot):
    """cache: (B,T,H,D); new: (B,1,H,D); slot: (B,1) -> write per batch."""
    b = cache.shape[0]
    bidx = jnp.arange(b)[:, None]
    return cache.at[bidx, slot].set(new.astype(cache.dtype))


def _window_positions(pos, window, t):
    """Absolute position stored in each rolling-cache slot (B, T)."""
    slots = jnp.arange(t)[None, :]
    cur_slot = (pos % window)[:, None]
    # slot s holds position: largest p' <= pos with p' % window == s
    delta = (cur_slot - slots) % window
    return pos[:, None] - delta


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str):
    t = min(max_len, cfg.window_size) if kind == "local" else max_len
    shape = (batch, t, cfg.phys_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, _dtype(cfg)),
        "v": jnp.zeros(shape, _dtype(cfg)),
    }


def init_paged_kv_cache(cfg: ModelConfig, num_blocks: int, block_size: int):
    """One layer's paged KV pool: (NB, block_size, Hkv, D), no batch dim —
    requests own pool blocks through their block tables (serve.kvpool)."""
    shape = (num_blocks, block_size, cfg.phys_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, _dtype(cfg)),
        "v": jnp.zeros(shape, _dtype(cfg)),
    }


def paged_prefill_update(kv, k, v, block_table, start, real_end):
    """Scatter a B=1 prefill chunk's K/V into the paged pool and gather the
    request's full contiguous view back.

    kv: {"k","v"}: (NB, bs, Hkv, D); k/v: (1, C, Hkv, D) chunk projections;
    block_table: (nblk,) int32 pool ids for the request's logical blocks
    (unallocated tail entries = scratch); start / real_end: scalar absolute
    positions — chunk row j holds position ``start + j`` and rows at
    positions >= real_end are bucket padding, whose writes are DROPPED
    (their block index is forced out of range with ``mode="drop"``) so pad
    garbage can never land in a block another request shares.

    Returns (new_kv, gathered_k, gathered_v) with gathered shapes
    (1, nblk*bs, Hkv, D)."""
    nb, bs = kv["k"].shape[:2]
    nblk = block_table.shape[0]
    c = k.shape[1]
    p = start + jnp.arange(c)
    pb = jnp.clip(p // bs, 0, nblk - 1)
    blk = jnp.where(p < real_end, block_table[pb], nb)  # nb => dropped
    off = p % bs
    ck = kv["k"].at[blk, off].set(k[0].astype(kv["k"].dtype), mode="drop")
    cv = kv["v"].at[blk, off].set(v[0].astype(kv["v"].dtype), mode="drop")
    gk = ck[block_table].reshape(1, nblk * bs, *ck.shape[2:])
    gv = cv[block_table].reshape(1, nblk * bs, *cv.shape[2:])
    return {"k": ck, "v": cv}, gk, gv


# ----------------------------------------------------------------------------
# FFN: SwiGLU / GeLU / KAN
# ----------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    if cfg.ffn_kind == "swiglu":
        return {
            "wi": jax.random.normal(ks[0], (d, f), dt) * sc_in,
            "wg": jax.random.normal(ks[1], (d, f), dt) * sc_in,
            "wo": jax.random.normal(ks[2], (f, d), dt) * sc_out,
        }
    if cfg.ffn_kind == "gelu":
        return {
            "wi": jax.random.normal(ks[0], (d, f), dt) * sc_in,
            "wo": jax.random.normal(ks[2], (f, d), dt) * sc_out,
        }
    if cfg.ffn_kind == "kan":
        nb = cfg.kan_grid + cfg.kan_order
        h = kan_ffn_hidden(cfg)
        # KANLinear pair: d -> h -> d; c:(in, nb, out), w_b:(in, out)
        return {
            "c1": jax.random.normal(ks[0], (d, nb, h), dt) * (0.1 / math.sqrt(d)),
            "wb1": jax.random.normal(ks[1], (d, h), dt) * sc_in,
            "c2": jax.random.normal(ks[2], (h, nb, d), dt) * (0.1 / math.sqrt(h)),
            "wb2": jax.random.normal(ks[0], (h, d), dt) * (1.0 / math.sqrt(h)),
        }
    if cfg.ffn_kind == "none":
        return {}
    raise ValueError(cfg.ffn_kind)


def kan_ffn_specs(cfg: ModelConfig) -> tuple:
    """Per-half ASPQuantSpecs of a KAN-FFN block (the d -> h -> d pair).

    ``cfg.kan_layer_bits`` (when set: one width per half) overrides the
    uniform ``cfg.kan_n_bits`` — KANtize-style mixed precision, PowerGap-
    validated per half; each half's lut_bits is clipped to its input width.
    """
    from ..core.asp_quant import resolve_layer_bits

    bits = resolve_layer_bits(
        cfg.kan_layer_bits if cfg.kan_layer_bits else cfg.kan_n_bits,
        2, cfg.kan_grid,
    )
    return tuple(
        ASPQuantSpec(
            grid_size=cfg.kan_grid, order=cfg.kan_order, n_bits=b,
            lut_bits=min(cfg.kan_n_bits, b), lo=-1.0, hi=1.0,
        )
        for b in bits
    )


def kan_ffn_spec(cfg: ModelConfig) -> ASPQuantSpec:
    """First-half spec (uniform deployments: THE spec; kept for callers
    that only need the bit-independent grid geometry)."""
    return kan_ffn_specs(cfg)[0]


def kan_ffn_hidden(cfg: ModelConfig) -> int:
    """KANLinear hidden width of a KAN-FFN block — the ONE place the rule
    lives; init_ffn and every geometry lookup (e.g. the serving engine's
    tuned-plan-source check) must agree on it."""
    nb = cfg.kan_grid + cfg.kan_order
    return cfg.kan_d_hidden or max(1, cfg.d_ff // nb)


def _bump_basis_and_grad(z, lo, hi, grid_size, order):
    """Cardinal-bump basis AND d(basis)/dz at z, both (..., G+K) f32."""
    from ..core.bspline import _cardinal_bump_coeffs

    h = (hi - lo) / grid_size
    tau = jnp.clip((z - lo) / h, 0.0, grid_size * (1 - 1e-7))
    interior = ((z - lo) / h > 0.0) & ((z - lo) / h < grid_size)
    g = jnp.floor(tau)
    u = tau - g
    g = g.astype(jnp.int32)
    coeffs = _cardinal_bump_coeffs(order)
    nb = grid_size + order
    iota = jnp.arange(nb, dtype=jnp.int32)
    basis = jnp.zeros(z.shape + (nb,), jnp.float32)
    dbasis = jnp.zeros(z.shape + (nb,), jnp.float32)
    for d in range(order + 1):
        seg = order - d
        val = jnp.zeros_like(u)
        dval = jnp.zeros_like(u)
        for p in reversed(range(order + 1)):  # simultaneous Horner: p, p'
            dval = dval * u + val
            val = val * u + float(coeffs[seg, p])
        hit = iota == (g + d)[..., None]
        basis = basis + jnp.where(hit, val[..., None], 0.0)
        dbasis = dbasis + jnp.where(hit, dval[..., None], 0.0)
    dbasis = dbasis * (interior[..., None] / h)  # clip grad + chain rule
    return basis, dbasis


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _spline_mm(x, c, lo, hi_g_k, _tag):
    hi, g, k = hi_g_k
    basis = bspline_basis_fast(jnp.tanh(x.astype(jnp.float32)), lo, hi, g, k)
    return jnp.einsum("bsfn,fno->bso", basis.astype(c.dtype), c)


def _spline_mm_fwd(x, c, lo, hi_g_k, _tag):
    return _spline_mm(x, c, lo, hi_g_k, _tag), (x, c)


def _spline_mm_bwd(lo, hi_g_k, _tag, res, dy):
    """Backward that contracts the basis dim LOCALLY before any cross-shard
    reduction: the default autodiff all-reduces the (B,S,F,G+K) basis
    cotangent across the TP axis (measured 1.17 TB/dev on the KAN-FFN train
    cell); contracting to (B,S,F) first shrinks that 11x (§Perf cell 3)."""
    hi, g, k = hi_g_k
    x, c = res
    z = jnp.tanh(x.astype(jnp.float32))
    basis, dbasis = _bump_basis_and_grad(z, lo, hi, g, k)
    dc = jnp.einsum("bsfn,bso->fno", basis.astype(dy.dtype), dy)
    # NOTE: XLA still all-reduces this partial dot's (B,S,F,G+K) output
    # across the TP axis before our local n-contraction (eager AR placement;
    # bf16-casting the dot was also tried and changed nothing) — a shard_map
    # rewrite with explicit deferred psum is the remaining lever (§Perf).
    t = jnp.einsum("bso,fno->bsfn", dy, c).astype(jnp.float32)
    dz = jnp.sum(t * dbasis, axis=-1)             # local contraction over n
    dx = dz * (1.0 - z * z)                       # tanh chain
    return dx.astype(x.dtype), dc.astype(c.dtype)


_spline_mm.defvjp(_spline_mm_fwd, _spline_mm_bwd)


def _kan_linear(c, wb, x, cfg: ModelConfig):
    """Float KANLinear over (B, S, in): banded basis matmul + ReLU branch.

    Uses the ASP cardinal-bump basis builder (bspline_basis_fast, 4x less
    HBM traffic than Cox-de Boor) and a TP-aware custom VJP (§Perf cell 3)."""
    spec = kan_ffn_spec(cfg)
    y = _spline_mm(x, c, spec.lo, (spec.hi, spec.grid_size, spec.order),
                   "kanffn")
    return y + jax.nn.relu(x) @ wb


def ffn(p, x, cfg: ModelConfig):
    if cfg.ffn_kind == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    if cfg.ffn_kind == "gelu":
        return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
    if cfg.ffn_kind == "kan":
        if "l1" in p:
            # ASP-quantized deployed block (see core.kan_ffn_deploy.
            # quantize_kan_ffn_params_tree): both halves run through the
            # fused kan_spline Pallas pipeline, int codes across the boundary.
            from ..core.kan_ffn_deploy import kan_ffn_apply_quantized

            return kan_ffn_apply_quantized(p, x, cfg)
        h = _kan_linear(p["c1"], p["wb1"], x, cfg)
        return _kan_linear(p["c2"], p["wb2"], h, cfg)
    if cfg.ffn_kind == "none":
        return jnp.zeros_like(x)
    raise ValueError(cfg.ffn_kind)


# ----------------------------------------------------------------------------
# MoE (top-k, sort-based dispatch, capacity drop)
# ----------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * sc_in,
        "wi": jax.random.normal(ks[1], (e, d, f), dt) * sc_in,
        "wg": jax.random.normal(ks[2], (e, d, f), dt) * sc_in,
        "wo": jax.random.normal(ks[3], (e, f, d), dt) * sc_out,
    }


def moe(p, x, cfg: ModelConfig):
    """Top-k MoE with sort-based dispatch into (E, C, D) expert batches."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32)) @ p["router"]
    topv, topi = jax.lax.top_k(logits, k)            # (T, k)
    gates = jax.nn.softmax(topv, axis=-1)            # normalize over chosen

    cap = int(max(1, math.ceil(t * k * cfg.moe_capacity_factor / e)))
    flat_e = topi.reshape(t * k)
    flat_g = gates.reshape(t * k)
    tok_id = jnp.repeat(jnp.arange(t), k)

    # Position-within-expert, two lowerings (cfg.moe_dispatch, §Perf):
    #  * "cumsum": one-hot prefix sums — avoids the GLOBAL token sort that
    #    XLA lowers to an all-gather of every token (8.6 GB f32 all-reduces
    #    per layer measured on olmoe's 64-expert dispatch);
    #  * "sort": argsort-based ranking — measured better for few-expert
    #    models (mixtral, E=8) where the sort is cheap and cumsum's
    #    (t·k, E) prefix chain serializes.
    if cfg.moe_dispatch == "sort":
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos_sorted = jnp.arange(t * k) - first
        inv = jnp.zeros_like(order).at[order].set(jnp.arange(t * k))
        pos = pos_sorted[inv]
    else:
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (t*k, E)
        pos = jnp.cumsum(onehot, axis=0) - 1                 # rank per expert
        pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    dest = jnp.where(keep, flat_e * cap + pos, e * cap)      # drop slot at end

    xe = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(xt[tok_id])
    xe = xe[: e * cap].reshape(e, cap, d)

    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"])

    ye_flat = jnp.concatenate([ye.reshape(e * cap, d),
                               jnp.zeros((1, d), ye.dtype)], axis=0)
    contrib = ye_flat[dest] * flat_g[:, None].astype(ye.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok_id].add(contrib)
    return out.reshape(b, s, d)


# ----------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ----------------------------------------------------------------------------


def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.rnn_width or d
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    return {
        "w_in": jax.random.normal(ks[0], (d, w), dt) * sc,
        "w_gate_in": jax.random.normal(ks[1], (d, w), dt) * sc,
        "conv": jax.random.normal(ks[2], (4, w), dt) * 0.3,
        "w_rg": jax.random.normal(ks[3], (w, w), dt) * (1.0 / math.sqrt(w)),
        "w_ig": jax.random.normal(ks[4], (w, w), dt) * (1.0 / math.sqrt(w)),
        "lam": jnp.full((w,), 2.0, jnp.float32),  # softplus-param of decay
        "w_out": jax.random.normal(ks[5], (w, d), dt) * (1.0 / math.sqrt(w)),
    }


def _causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x: (B,S,W), w: (K,W).  state: (B,K-1,W)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1) :] if k > 1 else None
    return out, new_state


def _rglru_scan(a, bx):
    """Associative linear recurrence h_t = a_t * h_{t-1} + b_t over axis 1."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return bb


def rglru(p, x, cfg: ModelConfig, state=None, pos=None):
    """x: (B,S,D). state: {"conv": (B,3,W), "h": (B,W)} for decode (S==1).
    Returns (out, new_state)."""
    decode = state is not None
    u = x @ p["w_in"]
    gate_in = jax.nn.gelu(x @ p["w_gate_in"])
    u, conv_state = _causal_conv1d(
        u, p["conv"], state["conv"] if decode else None
    )
    r = jax.nn.sigmoid(u @ p["w_rg"])
    i = jax.nn.sigmoid(u @ p["w_ig"])
    c = 8.0
    log_a = -c * jax.nn.softplus(p["lam"])[None, None, :] * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i * u).astype(jnp.float32) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)
    )
    if decode:
        h = a[:, 0] * state["h"] + gated[:, 0]
        out_seq = h[:, None, :]
        new_state = {"conv": conv_state, "h": h}
    else:
        out_seq = _rglru_scan(a, gated)
        new_state = None
    y = (out_seq.astype(x.dtype) * gate_in) @ p["w_out"]
    return y, new_state


def init_rglru_state(cfg: ModelConfig, batch: int):
    w = cfg.rnn_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, w), _dtype(cfg)),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_prefill(p, x, cfg: ModelConfig):
    """Full-sequence RG-LRU that also returns the final recurrent state."""
    u = x @ p["w_in"]
    gate_in = jax.nn.gelu(x @ p["w_gate_in"])
    u_conv, _ = _causal_conv1d(u, p["conv"])
    r = jax.nn.sigmoid(u_conv @ p["w_rg"])
    i = jax.nn.sigmoid(u_conv @ p["w_ig"])
    c = 8.0
    log_a = -c * jax.nn.softplus(p["lam"])[None, None, :] * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i * u_conv).astype(jnp.float32) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)
    )
    h_seq = _rglru_scan(a, gated)
    y = (h_seq.astype(x.dtype) * gate_in) @ p["w_out"]
    k = p["conv"].shape[0]
    state = {
        "conv": u[:, -(k - 1):, :].astype(_dtype(cfg)),
        "h": h_seq[:, -1, :],
    }
    return y, state


# ----------------------------------------------------------------------------
# Mamba-2 SSD block
# ----------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    hd = cfg.ssm_head_dim
    nh = din // hd
    n = cfg.ssm_state
    dt = _dtype(cfg)
    ks = jax.random.split(key, 5)
    sc = 1.0 / math.sqrt(d)
    return {
        "w_in": jax.random.normal(ks[0], (d, 2 * din + 2 * n + nh), dt) * sc,
        "conv": jax.random.normal(ks[1], (cfg.ssm_conv, din + 2 * n), dt) * 0.3,
        "a_log": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((din,), jnp.float32),
        "w_out": jax.random.normal(ks[4], (din, d), dt) * (1.0 / math.sqrt(din)),
    }


def _ssd_chunked(x, dtv, a_log, b, c, chunk: int):
    """SSD (state-space duality) chunked scan.

    x: (B,S,H,P) values; dtv: (B,S,H) step sizes (softplus'd);
    b,c: (B,S,N) input/output projections (single group);
    Returns y: (B,S,H,P).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)
    # decay per step: da = dt * A  (A = -exp(a_log) < 0)
    a = -jnp.exp(a_log)[None, None, :]            # (1,1,H)
    da = dtv * a                                   # (B,S,H) negative
    xz = (x * dtv[..., None]).astype(jnp.float32)  # fold dt into input

    da_c = da.reshape(bsz, nc, chunk, h)
    x_c = xz.reshape(bsz, nc, chunk, h, p)
    b_c = b.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    c_c = c.astype(jnp.float32).reshape(bsz, nc, chunk, n)

    cums = jnp.cumsum(da_c, axis=2)                # (B,NC,Q,H)
    # --- intra-chunk (diagonal blocks)
    # L[q, t] = exp(cums[q] - cums[t]) for t <= q.
    # (Storing L in bf16 was tried and REFUTED: XLA upcasts for the f32 dot,
    # traffic unchanged — §Perf mamba2 iteration log.)
    rel = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (B,NC,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    l_mat = jnp.exp(rel) * tri[None, None, :, :, None]
    cb = jnp.einsum("bcqn,bctn->bcqt", c_c, b_c)   # (B,NC,Q,Q)
    y_diag = jnp.einsum("bcqt,bcqth,bcthp->bcqhp", cb, l_mat, x_c)

    # --- chunk states: state_c = sum_t exp(cums[last]-cums[t]) * b_t x_t
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)        # (B,NC,Q,H)
    states = jnp.einsum("bctn,bcth,bcthp->bchnp", b_c, decay_to_end, x_c)

    # --- inter-chunk recurrence over NC (sequential scan, NC is small)
    chunk_decay = jnp.exp(cums[:, :, -1, :])                 # (B,NC,H)

    def scan_fn(carry, inp):
        dec, st = inp                                        # (B,H), (B,H,N,P)
        new = carry * dec[..., None, None] + st
        return new, carry                                    # emit PREVIOUS

    init = jnp.zeros((bsz, h, n, p), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1)),
    )
    prev_states = prev_states.swapaxes(0, 1)                 # (B,NC,H,N,P)

    # --- inter-chunk contribution
    decay_from_start = jnp.exp(cums)                         # (B,NC,Q,H)
    y_off = jnp.einsum(
        "bcqn,bcqh,bchnp->bcqhp", c_c, decay_from_start, prev_states
    )
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final_state


def mamba2(p, x, cfg: ModelConfig, state=None):
    """Mamba-2 block. x: (B,S,D). state (decode): {"conv": (B,K-1,Cw),
    "ssm": (B,H,N,P)}. Returns (y, new_state)."""
    bsz, s, d = x.shape
    din = cfg.ssm_expand * d
    hd = cfg.ssm_head_dim
    nh = din // hd
    n = cfg.ssm_state
    decode = state is not None

    zxbcdt = x @ p["w_in"]
    z, xin, bc, dtv = jnp.split(zxbcdt, [din, 2 * din, 2 * din + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out, conv_state = _causal_conv1d(
        conv_in, p["conv"], state["conv"] if decode else None
    )
    conv_out = jax.nn.silu(conv_out)
    xin, b, c = jnp.split(conv_out, [din, din + n], axis=-1)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    xh = xin.reshape(bsz, s, nh, hd)
    if decode:
        a = -jnp.exp(p["a_log"])[None, :]                     # (1,H)
        da = jnp.exp(dtv[:, 0] * a)                           # (B,H)
        xz = (xh[:, 0] * dtv[:, 0, :, None]).astype(jnp.float32)
        new_ssm = state["ssm"] * da[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", b[:, 0].astype(jnp.float32), xz
        )
        y = jnp.einsum("bn,bhnp->bhp", c[:, 0].astype(jnp.float32), new_ssm)
        y = y[:, None]                                        # (B,1,H,P)
        new_state = {"conv": conv_state, "ssm": new_ssm}
    else:
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtv_p = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dtv_p, b_p, c_p = xh, dtv, b, c
        y, final_ssm = _ssd_chunked(xh_p, dtv_p, p["a_log"], b_p, c_p, chunk)
        y = y[:, :s]
        new_state = {
            "conv": conv_in[:, -(cfg.ssm_conv - 1):, :].astype(x.dtype),
            "ssm": final_ssm,
        }
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, din)
    # gated RMSNorm (mamba2 style)
    yf = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["norm"])
    return yf.astype(x.dtype) @ p["w_out"], new_state


def mamba2_prefill(p, x, cfg: ModelConfig):
    """Full-sequence Mamba-2 that also returns the final SSD/conv state."""
    return mamba2(p, x, cfg, state=None)


def init_mamba2_state(cfg: ModelConfig, batch: int):
    din = cfg.ssm_expand * cfg.d_model
    nh = din // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, din + 2 * cfg.ssm_state), _dtype(cfg)),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
    }
