"""Model assembly: embeddings, stacks, heads; train/prefill/decode entries.

Families:
  * dense/moe/ssm/hybrid: decoder-only LM over tokens.
  * audio (whisper): encoder over STUB frame embeddings (the conv frontend is
    out of scope per the assignment; ``input_specs`` supplies precomputed
    (B, enc_seq, d_model) frames) + decoder with cross-attention.
  * vlm (pixtral): STUB patch embeddings (B, num_patches, patch_embed_dim)
    projected and prepended to the token sequence.

Batch dicts:
  train:   {"tokens": (B,S) int32, "targets": (B,S) int32}  (+stub embeds)
  prefill: {"tokens": (B,S)}  (+stub embeds)
  decode:  {"token": (B,) int32, "pos": (B,) int32} + cache
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .transformer import (
    init_stack,
    init_stack_cache,
    init_stack_cache_paged,
    stack_decode,
    stack_forward,
    stack_prefill,
    stack_prefill_paged,
)

Params = Any


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    p: dict = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), dt)
        * 0.02,
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "decoder": init_stack(keys[1], cfg, cross=cfg.encoder_layers > 0),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(keys[2], (cfg.d_model, cfg.vocab_size), dt) * 0.02
        )
    if cfg.encoder_layers > 0:
        enc_cfg = dataclasses.replace(
            cfg,
            num_layers=cfg.encoder_layers,
            attn_pattern=("bidir",),
            num_experts=0,
        )
        p["encoder"] = init_stack(keys[3], enc_cfg, cross=False)
        p["enc_norm"] = L.init_rmsnorm(cfg.d_model)
    if cfg.family == "vlm":
        p["patch_proj"] = (
            jax.random.normal(keys[4], (cfg.patch_embed_dim, cfg.d_model), dt)
            * (1.0 / jnp.sqrt(cfg.patch_embed_dim).astype(jnp.float32))
        ).astype(dt)
    return p


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, num_layers=cfg.encoder_layers, attn_pattern=("bidir",), num_experts=0
    )


# ----------------------------------------------------------------------------
# embedding / head
# ----------------------------------------------------------------------------


def _embed_tokens(p, tokens, cfg: ModelConfig):
    h = jnp.take(p["embed"], tokens, axis=0)
    return h * jnp.asarray(jnp.sqrt(float(cfg.d_model)), h.dtype)


def _lm_logits(p, h, cfg: ModelConfig):
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = (h @ w).astype(jnp.float32)
    return L.softcap(logits, cfg.final_logit_softcap)


def _encode(p, batch, cfg: ModelConfig):
    """Whisper encoder over stub frame embeddings (+ sinusoidal positions)."""
    frames = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
    pos = L.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)
    h = frames + pos[None]
    h = stack_forward(p["encoder"], h, _encoder_cfg(cfg))
    return L.rmsnorm(p["enc_norm"], h, cfg.norm_eps)


def _prepend_patches(p, h_tokens, batch, cfg: ModelConfig):
    patches = batch["patch_embeds"].astype(jnp.dtype(cfg.dtype)) @ p["patch_proj"]
    return jnp.concatenate([patches, h_tokens], axis=1)


# ----------------------------------------------------------------------------
# forward / loss (training + evaluation)
# ----------------------------------------------------------------------------


def forward(p, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = _embed_tokens(p, tokens, cfg)
    enc_out = None
    n_prefix = 0
    if cfg.encoder_layers > 0:
        enc_out = _encode(p, batch, cfg)
    if cfg.family == "vlm":
        h = _prepend_patches(p, h, batch, cfg)
        n_prefix = h.shape[1] - s
    positions = jnp.broadcast_to(jnp.arange(h.shape[1])[None], h.shape[:2])
    h = stack_forward(p["decoder"], h, cfg, positions=positions, enc_out=enc_out)
    h = L.rmsnorm(p["final_norm"], h, cfg.norm_eps)
    if n_prefix:
        h = h[:, n_prefix:]
    return _lm_logits(p, h, cfg)


def loss_fn(p, batch, cfg: ModelConfig):
    logits = forward(p, batch, cfg)
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


# ----------------------------------------------------------------------------
# serving: prefill + single-token decode
# ----------------------------------------------------------------------------


def init_cache(p, cfg: ModelConfig, batch: int, max_len: int):
    enc_len = cfg.enc_seq if cfg.encoder_layers > 0 else 0
    return init_stack_cache(cfg, p["decoder"], batch, max_len, enc_len=enc_len)


def prefill(p, batch, cfg: ModelConfig, max_len: int, last_index=None):
    """Process the prompt; returns (last-token logits, filled cache).

    ``last_index``: optional (B,) int32 of the last REAL token position per
    row — the serving engine pads prompts to power-of-two length buckets
    (one compile per bucket instead of per length) and reads the first-token
    logits at the true prompt end instead of the padded one.  Passed as a
    traced array so varying it never retraces.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = _embed_tokens(p, tokens, cfg)
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = _encode(p, batch, cfg)
    if cfg.family == "vlm":
        h = _prepend_patches(p, h, batch, cfg)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1])[None], h.shape[:2])
    cache = init_cache(p, cfg, b, max_len)
    h, cache = stack_prefill(p["decoder"], cache, h, cfg, positions=positions,
                             enc_out=enc_out)
    h = L.rmsnorm(p["final_norm"], h, cfg.norm_eps)
    if last_index is None:
        sel = h[:, -1:, :]
    else:
        n_prefix = h.shape[1] - s
        idx = (n_prefix + last_index).astype(jnp.int32)
        sel = jnp.take_along_axis(h, idx[:, None, None], axis=1)
    return _lm_logits(p, sel, cfg)[:, 0], cache


def decode_step(p, cache, token, pos, cfg: ModelConfig, block_table=None):
    """token: (B,) int32; pos: (B,) int32.  Returns (logits (B,V), cache).

    ``block_table`` ((B, nblk) int32) switches the attention layers to the
    paged KV pool (cache leaves (repeats, NB, bs, H, D)); omitted, the
    contiguous per-slot cache is used unchanged."""
    h = _embed_tokens(p, token[:, None], cfg)
    h, cache = stack_decode(p["decoder"], cache, h, pos, cfg,
                            block_table=block_table)
    h = L.rmsnorm(p["final_norm"], h, cfg.norm_eps)
    return _lm_logits(p, h, cfg)[:, 0], cache


def verify_step(p, cache, tokens, pos, cfg: ModelConfig, block_table):
    """Speculative-decode verify pass: score S consecutive tokens in one
    batched forward.  tokens: (B, S) int32 — row i holds the last emitted
    token followed by that slot's S-1 draft tokens, occupying positions
    pos[i]..pos[i]+S-1; pos: (B,) int32.  Returns (logits (B, S, V),
    cache).  Row j of the logits is the target's next-token distribution
    after tokens[:, :j+1] — exactly what ``decode_step`` would have
    produced token-by-token (attention over a causal frontier per row,
    same paged scatter-then-gather), so greedy acceptance against these
    rows is bit-identical to the sequential baseline."""
    h = _embed_tokens(p, tokens, cfg)
    h, cache = stack_decode(p["decoder"], cache, h, pos, cfg,
                            block_table=block_table)
    h = L.rmsnorm(p["final_norm"], h, cfg.norm_eps)
    return _lm_logits(p, h, cfg), cache


def init_paged_cache(p, cfg: ModelConfig, num_blocks: int, block_size: int):
    """Paged KV pool shared by every slot (see serve.kvpool); pure
    global-attention decoders only."""
    if cfg.encoder_layers > 0:
        raise ValueError("paged KV cache does not support encoder prefixes")
    return init_stack_cache_paged(cfg, p["decoder"], num_blocks, block_size)


def prefill_chunk(p, tokens, cache, block_table, start, real_end, cfg:
                  ModelConfig, last_index):
    """Advance one B=1 prefill chunk against the paged KV pool.

    tokens: (1, C) int32 — prompt slice [start, start+C), right-padded with
    token 0 to a length bucket; positions >= ``real_end`` are padding (their
    KV writes are dropped).  ``block_table``: (nblk,) pool ids for the
    request; ``last_index``: absolute index of the LAST real prompt token —
    the returned (1, V) logits row is read there (meaningful only on the
    final chunk; earlier chunks return a garbage row the caller ignores,
    keeping one trace for all chunks).  Returns (logits, cache)."""
    b, s = tokens.shape
    h = _embed_tokens(p, tokens, cfg)
    positions = start + jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h, cache = stack_prefill_paged(p["decoder"], cache, h, cfg, block_table,
                                   start, real_end, positions=positions)
    h = L.rmsnorm(p["final_norm"], h, cfg.norm_eps)
    idx = jnp.clip(last_index - start, 0, s - 1).astype(jnp.int32)
    sel = jnp.take_along_axis(
        h, jnp.broadcast_to(idx, (b,))[:, None, None], axis=1
    )
    return _lm_logits(p, sel, cfg)[:, 0], cache
