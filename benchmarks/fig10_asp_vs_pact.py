"""Fig. 10: ASP-KAN-HAQ vs conventional (PACT-based) quantization —
normalized area and energy of the B(X) path, G in {8,16,32,64}.

Paper claims: avg area reduction 40.14x, avg energy reduction 5.59x,
improvements growing with G.
"""

from __future__ import annotations

import numpy as np

from repro.core.asp_quant import ASPQuantSpec
from repro.core.costmodel import bx_path_asp, bx_path_conventional

PAPER_AVG_AREA = 40.14
PAPER_AVG_ENERGY = 5.59

GRIDS = (8, 16, 32, 64)


def run(print_fn=print) -> dict:
    rows = []
    for g in GRIDS:
        spec = ASPQuantSpec(grid_size=g, order=3, n_bits=8, lut_bits=8,
                            lo=0.0, hi=1.0)
        conv = bx_path_conventional(spec)
        asp = bx_path_asp(spec)
        rows.append({
            "G": g,
            "LD": spec.ld,
            "conv_area_um2": conv["area_um2"],
            "asp_area_um2": asp["area_um2"],
            "area_ratio": conv["area_um2"] / asp["area_um2"],
            "conv_energy_pj": conv["energy_pj"],
            "asp_energy_pj": asp["energy_pj"],
            "energy_ratio": conv["energy_pj"] / asp["energy_pj"],
        })
    avg_area = float(np.mean([r["area_ratio"] for r in rows]))
    avg_energy = float(np.mean([r["energy_ratio"] for r in rows]))

    print_fn("fig10: B(X) path, conventional(PACT) vs ASP-KAN-HAQ (22nm model)")
    print_fn("G,LD,conv_area,asp_area,area_ratio,conv_energy,asp_energy,energy_ratio")
    for r in rows:
        print_fn(
            f"{r['G']},{r['LD']},{r['conv_area_um2']:.0f},{r['asp_area_um2']:.0f},"
            f"{r['area_ratio']:.1f},{r['conv_energy_pj']:.2f},"
            f"{r['asp_energy_pj']:.2f},{r['energy_ratio']:.2f}"
        )
    print_fn(f"avg_area_ratio,{avg_area:.2f},paper,{PAPER_AVG_AREA}")
    print_fn(f"avg_energy_ratio,{avg_energy:.2f},paper,{PAPER_AVG_ENERGY}")
    return {"rows": rows, "avg_area_ratio": avg_area, "avg_energy_ratio": avg_energy}


if __name__ == "__main__":
    run()
