"""Benchmark harness: one entry per paper table/figure + kernel microbench +
roofline aggregation.  ``python -m benchmarks.run [--fast]``.

Prints ``name,us_per_call,derived`` CSV blocks per benchmark.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def _bench_kernel(print_fn=print):
    """Microbenchmark the two Pallas kernels (interpret mode on CPU: the
    numbers validate plumbing, not TPU perf — TPU perf comes from §Roofline)."""
    from repro.core.asp_quant import ASPQuantSpec, build_lut
    from repro.kernels.kan_spline.ops import kan_spline
    from repro.kernels.kan_spline.ref import kan_spline_ref

    spec = ASPQuantSpec(grid_size=8, order=3, n_bits=8, lo=-1.0, hi=1.0)
    e = build_lut(spec)
    lut = jnp.asarray(e["lut_q"] * e["scale"], jnp.float32)
    key = jax.random.PRNGKey(0)
    codes = jax.random.randint(key, (256, 128), 0, spec.num_codes)
    wc = jax.random.normal(key, (128, spec.num_basis, 128)) * 0.3
    wb = jax.random.normal(key, (128, 128)) * 0.3

    ref = jax.jit(lambda c: kan_spline_ref(c, lut, wc, wb, spec))
    ref(codes).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        ref(codes).block_until_ready()
    t_ref = (time.perf_counter() - t0) / 10 * 1e6
    print_fn(f"kan_spline_ref_jit,{t_ref:.0f},us_per_call (B=256 F=128 O=128)")

    out = kan_spline(codes, lut, wc, wb, spec, interpret=True)
    err = float(jnp.abs(out - ref(codes)).max())
    print_fn(f"kan_spline_pallas_interpret,allclose_err,{err:.2e}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced training budgets (CI-speed)")
    ap.add_argument("--skip", default="",
                    help="comma-list: fig10,fig11,fig12,fig13,kernels,roofline")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()

    from benchmarks.fig10_asp_vs_pact import run as fig10
    from benchmarks.fig11_input_generators import run as fig11
    from benchmarks.fig12_kan_sam import run as fig12
    from benchmarks.fig13_knot_e2e import run as fig13
    from benchmarks.roofline import run as roofline

    t0 = time.time()
    if "fig10" not in skip:
        fig10()
        print()
    if "fig11" not in skip:
        fig11()
        print()
    if "kernels" not in skip:
        _bench_kernel()
        print()
    if "fig12" not in skip:
        fig12(fast=args.fast)
        print()
    if "fig13" not in skip:
        fig13(fast=args.fast)
        print()
    if "roofline" not in skip:
        roofline()
    print(f"\ntotal_bench_time_s,{time.time()-t0:.0f}")


if __name__ == "__main__":
    main()
