"""Benchmark harness: one entry per paper table/figure + kernel microbench +
fused-pipeline/runtime-backend bench + roofline aggregation.
``python -m benchmarks.run [--fast]``.

Prints ``name,us_per_call,derived`` CSV blocks per benchmark.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def _bench_kernel(print_fn=print):
    """Microbenchmark the two Pallas kernels (interpret mode on CPU: the
    numbers validate plumbing, not TPU perf — TPU perf comes from §Roofline)."""
    from repro.core.asp_quant import ASPQuantSpec, build_lut
    from repro.kernels.kan_spline.ops import kan_spline
    from repro.kernels.kan_spline.ref import kan_spline_ref

    spec = ASPQuantSpec(grid_size=8, order=3, n_bits=8, lo=-1.0, hi=1.0)
    e = build_lut(spec)
    lut = jnp.asarray(e["lut_q"] * e["scale"], jnp.float32)
    key = jax.random.PRNGKey(0)
    codes = jax.random.randint(key, (256, 128), 0, spec.num_codes)
    wc = jax.random.normal(key, (128, spec.num_basis, 128)) * 0.3
    wb = jax.random.normal(key, (128, 128)) * 0.3

    ref = jax.jit(lambda c: kan_spline_ref(c, lut, wc, wb, spec))
    ref(codes).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        ref(codes).block_until_ready()
    t_ref = (time.perf_counter() - t0) / 10 * 1e6
    print_fn(f"kan_spline_ref_jit,{t_ref:.0f},us_per_call (B=256 F=128 O=128)")

    out = kan_spline(codes, lut, wc, wb, spec, interpret=True)
    err = float(jnp.abs(out - ref(codes)).max())
    print_fn(f"kan_spline_pallas_interpret,allclose_err,{err:.2e}")


def _bench_runtime(print_fn=print):
    """The REAL serving hot path: the fused multi-layer pipeline through the
    runtime's backend registry (ref / pallas / acim), not just the
    single-layer kernel.  Uses the FFN-width geometry (64, 128, 64) so the
    numbers line up with bench_kan_pipeline's deployment rows; off-TPU the
    Pallas path runs in interpret mode (plumbing validation, not TPU perf).
    """
    import time

    from repro import runtime
    from repro.core.kan_layer import KANSpec, init_kan_network
    from repro.core.kan_network_deploy import (
        default_interpret,
        deploy_kan_network,
        kan_network_deploy_apply,
        quantize_kan_network,
    )

    interpret = default_interpret()
    kspec = KANSpec(dims=(64, 128, 64), grid_size=8)
    key = jax.random.PRNGKey(0)
    qparams = quantize_kan_network(init_kan_network(key, kspec), kspec)
    dep = deploy_kan_network(qparams, kspec, batch=64)
    x = jax.random.uniform(key, (64, 64), minval=-1.0, maxval=1.0)
    runtime.reset_cache()
    for backend in ("ref", "pallas", "acim"):
        fn = lambda x, b=backend: kan_network_deploy_apply(
            dep, x, interpret=interpret, backend=b,
            key=jax.random.PRNGKey(0) if b == "acim" else None,
        )
        fn(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            fn(x).block_until_ready()
        us = (time.perf_counter() - t0) / 10 * 1e6
        print_fn(f"kan_pipeline_runtime_{backend},{us:.0f},"
                 "us_per_call (B=64 dims=64-128-64 G=8)")
    err = float(jnp.abs(
        kan_network_deploy_apply(dep, x, interpret=interpret, backend="pallas")
        - kan_network_deploy_apply(dep, x, interpret=interpret, backend="ref")
    ).max())
    print_fn(f"kan_pipeline_fused_vs_ref,allclose_err,{err:.2e}")
    print_fn(f"kan_pipeline_plan_cache,{runtime.cache_stats()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced training budgets (CI-speed)")
    ap.add_argument("--skip", default="",
                    help="comma-list: fig10,fig11,fig12,fig13,kernels,"
                         "runtime,roofline")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()

    from benchmarks.fig10_asp_vs_pact import run as fig10
    from benchmarks.fig11_input_generators import run as fig11
    from benchmarks.fig12_kan_sam import run as fig12
    from benchmarks.fig13_knot_e2e import run as fig13
    from benchmarks.roofline import run as roofline

    t0 = time.time()
    if "fig10" not in skip:
        fig10()
        print()
    if "fig11" not in skip:
        fig11()
        print()
    if "kernels" not in skip:
        _bench_kernel()
        print()
    if "runtime" not in skip:
        _bench_runtime()
        print()
    if "fig12" not in skip:
        fig12(fast=args.fast)
        print()
    if "fig13" not in skip:
        fig13(fast=args.fast)
        print()
    if "roofline" not in skip:
        roofline()
    print(f"\ntotal_bench_time_s,{time.time()-t0:.0f}")


if __name__ == "__main__":
    main()
