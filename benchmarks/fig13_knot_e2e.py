"""Fig. 13: end-to-end knot-theory comparison — traditional MLP accelerator
vs KAN1 (minimal HW constraints) vs KAN2 (moderate HW constraints).

Full KAN-NeuroSim pipeline: hardware design point per paper (KAN1: G=5,
8-bit, TD-P, 128-row arrays; KAN2: G=68, 10-bit, 1024-row arrays), cost from
the 22nm model, accuracy from training on the knot surrogate with the
quantized+ACIM evaluation path (KAN-SAM enabled).

Paper table:
            MLP        KAN1     KAN2
  Area      0.585 mm2  0.014    0.063
  Energy    20049 pJ   257.13   392.76
  Latency   19632 ns   664      832
  #Param    190214     279      2232
  Accuracy  78%        81.03%   86.74%
Headline: 41.78x area, 77.97x energy, 23.59-29.56x latency, +3.03% accuracy.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.asp_quant import ASPQuantSpec
from repro.core.cim import CIMConfig
from repro.core.costmodel import accelerator_cost, kan_accelerator, mlp_accelerator
from repro.core.kan_layer import KANSpec, param_count
from repro.core.mlp_baseline import (
    PAPER_MLP_DIMS,
    init_mlp,
    mlp_param_count,
    train_mlp,
)
from repro.core.neurosim import evaluate_accuracy, evaluate_accuracy_cim, train_kan
from repro.core.tmdv import PURE_PWM, TMDVConfig
from repro.data.knot import make_knot_dataset

PAPER = {
    "MLP": {"area": 0.585, "energy": 20049.28, "latency": 19632,
            "params": 190214, "acc": 0.78},
    "KAN1": {"area": 0.014, "energy": 257.13, "latency": 664,
             "params": 279, "acc": 0.8103},
    "KAN2": {"area": 0.063, "energy": 392.76, "latency": 832,
             "params": 2232, "acc": 0.8674},
}

KAN_DIMS = (17, 1, 14)


def design_points():
    k1 = ASPQuantSpec(grid_size=5, order=3, n_bits=8, lut_bits=8, lo=-1.0, hi=1.0)
    k2 = ASPQuantSpec(grid_size=68, order=3, n_bits=10, lut_bits=10, lo=-1.0, hi=1.0)
    return {
        "MLP": accelerator_cost(mlp_accelerator(PAPER_MLP_DIMS, PURE_PWM(8))),
        "KAN1": accelerator_cost(
            kan_accelerator(KAN_DIMS, k1, TMDVConfig(8, 4), 128, adc_bits=8)),
        "KAN2": accelerator_cost(
            kan_accelerator(KAN_DIMS, k2, TMDVConfig(10, 6), 1024, adc_bits=10)),
    }


def run(print_fn=print, fast: bool = False, seed: int = 0) -> dict:
    n_train = 8192 if fast else 65536
    xt, yt, xv, yv = make_knot_dataset(n_train, 4096, seed=seed, label_noise=0.04)

    # --- accuracy: MLP
    mlp_epochs = 20 if fast else 60
    _, mlp_hist = train_mlp(init_mlp(jax.random.PRNGKey(seed + 1)), xt, yt,
                            xv, yv, epochs=mlp_epochs, lr=2e-3,
                            batch_size=8192)
    acc_mlp = max(mlp_hist)

    # --- accuracy: KANs (trained, then evaluated on the ACIM sim with SAM)
    def sched(total):
        def f(step):
            t = jnp.minimum(step / total, 1.0)
            return 2e-2 * 0.95 * (0.5 * (1 + jnp.cos(jnp.pi * t))) + 1e-3
        return f

    accs = {}
    for name, g, epochs in [("KAN1", 5, 40 if fast else 180),
                            ("KAN2", 68, 20 if fast else 100)]:
        kspec = KANSpec(dims=KAN_DIMS, grid_size=g)
        steps = epochs * max(1, n_train // 4096)
        params, _ = train_kan(kspec, xt, yt, xv, yv, epochs=epochs,
                              batch_size=4096, lr=sched(steps), seed=seed)
        sw = evaluate_accuracy(params, xv, yv, kspec)
        cim_cfg = CIMConfig(array_rows=128 if name == "KAN1" else 1024,
                            adc_bits=8 if name == "KAN1" else 10,
                            ir_gamma=0.10, sigma_ps_ref=0.35)
        hw = evaluate_accuracy_cim(params, xv, yv, kspec, cim_cfg,
                                   jax.random.PRNGKey(7), use_sam=True,
                                   calib_x=xt[:2048])
        accs[name] = {"sw": sw, "hw": hw}

    costs = design_points()
    rows = {
        "MLP": {**costs["MLP"], "params": mlp_param_count(), "acc": acc_mlp},
        "KAN1": {**costs["KAN1"],
                 "params": param_count(KANSpec(dims=KAN_DIMS, grid_size=5)),
                 "acc": accs["KAN1"]["hw"], "acc_sw": accs["KAN1"]["sw"]},
        "KAN2": {**costs["KAN2"],
                 "params": param_count(KANSpec(dims=KAN_DIMS, grid_size=68)),
                 "acc": accs["KAN2"]["hw"], "acc_sw": accs["KAN2"]["sw"]},
    }

    print_fn("fig13: knot-theory accelerators (ours vs paper)")
    print_fn("metric,MLP,KAN1,KAN2,paper_MLP,paper_KAN1,paper_KAN2")
    for metric, key, fmt in [("area_mm2", "area", "{:.4f}"),
                             ("energy_pj", "energy", "{:.1f}"),
                             ("latency_ns", "latency", "{:.0f}"),
                             ("params", "params", "{:d}"),
                             ("accuracy", "acc", "{:.3f}")]:
        ours = [rows[m]["area_mm2" if metric == "area_mm2" else
                        "energy_pj" if metric == "energy_pj" else
                        "latency_ns" if metric == "latency_ns" else
                        "params" if metric == "params" else "acc"]
                for m in ("MLP", "KAN1", "KAN2")]
        ref = [PAPER[m][key] for m in ("MLP", "KAN1", "KAN2")]
        print_fn(metric + "," + ",".join(fmt.format(o) if metric == "params"
                                         else f"{o:.4g}" for o in ours)
                 + "," + ",".join(f"{r}" for r in ref))
    h = {
        "area_x": rows["MLP"]["area_mm2"] / rows["KAN1"]["area_mm2"],
        "energy_x": rows["MLP"]["energy_pj"] / rows["KAN1"]["energy_pj"],
        "latency_x": rows["MLP"]["latency_ns"] / rows["KAN1"]["latency_ns"],
        "acc_delta_pp": 100 * (rows["KAN1"]["acc"] - rows["MLP"]["acc"]),
    }
    print_fn(
        f"headline,area x{h['area_x']:.1f} (41.78) energy x{h['energy_x']:.1f} "
        f"(77.97) latency x{h['latency_x']:.1f} (29.56) "
        f"acc {h['acc_delta_pp']:+.2f}pp (+3.03)"
    )
    return {"rows": rows, "headline": h}


if __name__ == "__main__":
    import sys
    run(fast="--fast" in sys.argv)
