"""Fig. 11: WL input-generator comparison at 6 bits (2^6 pulse benchmark).

Paper claims (vs TM-DV-IG, N=3): pure voltage 1.96x area, 11.9x power,
best latency; pure PWM 8x latency, 1.07x area; TM-DV FOM 3x / 4.1x better.
FOM = 1 / (area * power * latency).

Also reports the accuracy side (charge-transfer error of each method under
the behavioral noise model) — the reason TM-DV wins the FOM without losing
MAC yield.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.costmodel import input_generator_cost
from repro.core.tmdv import PURE_PWM, PURE_VOLTAGE, TMDVConfig, apply_input_noise

PAPER = {
    "voltage_area_x": 1.96, "voltage_power_x": 11.9,
    "pwm_latency_x": 8.0, "pwm_area_x": 1.07,
    "fom_vs_voltage": 3.0, "fom_vs_pwm": 4.1,
}

BITS = 6


def _charge_rmse(cfg: TMDVConfig, key) -> float:
    codes = jnp.arange(2**cfg.total_bits).repeat(256)
    q = apply_input_noise(codes, cfg, key)
    return float(jnp.sqrt(jnp.mean((q - codes.astype(jnp.float32)) ** 2)))


def run(print_fn=print) -> dict:
    key = jax.random.PRNGKey(0)
    gens = {
        "pure_voltage": PURE_VOLTAGE(BITS),
        "pure_pwm": PURE_PWM(BITS),
        "tmdv": TMDVConfig(total_bits=BITS, voltage_bits=BITS // 2),
    }
    rows = {}
    for name, cfg in gens.items():
        c = input_generator_cost(cfg)
        c["charge_rmse_lsb"] = _charge_rmse(cfg, key)
        rows[name] = c

    t = rows["tmdv"]
    derived = {
        "voltage_area_x": rows["pure_voltage"]["area_um2"] / t["area_um2"],
        "voltage_power_x": rows["pure_voltage"]["power_uw"] / t["power_uw"],
        "pwm_latency_x": rows["pure_pwm"]["latency_ns"] / t["latency_ns"],
        "pwm_area_x": rows["pure_pwm"]["area_um2"] / t["area_um2"],
        "fom_vs_voltage": t["fom"] / rows["pure_voltage"]["fom"],
        "fom_vs_pwm": t["fom"] / rows["pure_pwm"]["fom"],
    }

    print_fn("fig11: WL input generators at 6 bits (22nm model)")
    print_fn("method,area_um2,power_uw,latency_ns,fom,charge_rmse_lsb")
    for name, c in rows.items():
        print_fn(
            f"{name},{c['area_um2']:.1f},{c['power_uw']:.3f},"
            f"{c['latency_ns']:.0f},{c['fom']:.2e},{c['charge_rmse_lsb']:.3f}"
        )
    print_fn("metric,ours,paper")
    for k, v in derived.items():
        print_fn(f"{k},{v:.2f},{PAPER[k]}")
    return {"rows": rows, "derived": derived}


if __name__ == "__main__":
    run()
