"""Benchmark the multi-layer KAN inference paths; seeds the perf trajectory.

Four executors over the same quantized network, all resolved through
``repro.runtime``:

  * ``float``      — kan_network_apply float path (Cox-de Boor basis, f32)
  * ``quant_ref``  — layered jnp quantized path (backend="ref"): per-layer
                     quantize / SH-LUT / banded matmul with f32 round-trips
                     between layers
  * ``fused``      — the fused Pallas pipeline (backend="pallas"): every
                     layer in the kan_spline kernel, inter-layer
                     requantization fused, int codes across boundaries
  * ``acim``       — the fused pipeline with the paper's measured RRAM-ACIM
                     non-idealities injected at the MAC stage (TM-DV input
                     noise, IR-drop, partial-sum sigma)

at the paper's KAN1 (17,1,14 / G=5) and KAN2 (G=68) edge configs and one
transformer-FFN width (the qwen2.5-14b smoke KAN-FFN geometry).  Each row
also reports executor throughput (rows through the KAN per second) and the
run ends with the runtime plan-cache hit/miss/trace counters plus a small
end-to-end served-tokens/s measurement of the continuous-batching engine on
the fused datapath.  A SUSTAINED section then drives the async scheduler
with a deterministic Poisson-ish arrival schedule of a mixed
shared-prefix/unique workload per runtime backend on the paged-KV engine
(block pool + prefix cache + chunked prefill), plus contiguous-slab and
prefix-cache-off comparison legs, recording TTFT p50/p95, inter-token
latency, tokens/s, queue-depth trace and the KV pool's hit-rate /
peak-blocks counters (the docs/serving.md metrics glossary).  Speculative
legs rerun the paged schedule per backend with ``spec_decode=2`` (a cheap
halved-grid KAN drafter + one-pass batched verify; greedy streams stay
bit-identical), recording accept rate, tokens-per-round and draft/verify
p50 next to the spec-off baselines.  An ATTENTION
section times the decode step per attention backend ("ref" chunked XLA vs
"flash" fused Pallas) on the KAN-deployed engine — with "flash" every
FLOP-heavy op of the step is a fused kernel — plus a prefill-shape SDPA
microbench.  A SHARDED section then times the mesh-sharded runtime
(data-only and data x model meshes over every host device, plus a
mesh-sharded engine leg), recording mesh shape and device count so the perf
trajectory captures scaling — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to exercise it on a
CPU container.  ``--tuned`` adds a heuristic-plan vs tuned-plan leg:
``repro.tune.tiles`` sweeps tile geometries for each config (measured on
TPU, deterministic cost proxy in interpret mode), registers the winner with
the plan cache, and the fused executor is re-timed on it.  Off-TPU the
Pallas path runs in interpret mode — those numbers validate plumbing, not
TPU perf (same caveat as benchmarks/run.py's kernel microbench).

    PYTHONPATH=src python benchmarks/bench_kan_pipeline.py --out BENCH_kan_pipeline.json
    PYTHONPATH=src python benchmarks/bench_kan_pipeline.py --smoke --tuned  # CI step
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import runtime
from repro.core.cim import CIMConfig
from repro.core.kan_layer import KANSpec, init_kan_network, kan_network_apply
from repro.core.kan_network_deploy import (
    default_interpret,
    deploy_kan_network,
    kan_network_deploy_apply,
    quantize_kan_network,
)

CONFIGS = [
    # (name, dims, grid)  — KAN1/KAN2 are the paper's edge nets (§4);
    # ffn_width is the LM deployment surface (models/layers KAN-FFN smoke).
    ("kan1_17_1_14_g5", (17, 1, 14), 5),
    ("kan2_17_1_14_g68", (17, 1, 14), 68),
    ("ffn_64_128_64_g8", (64, 128, 64), 8),
]

# The measured 22nm calibration used by examples/knot_e2e.py.
ACIM_CFG = CIMConfig(ir_gamma=0.06, sigma_ps_ref=0.05)


def _time_fn(fn, x, repeats: int) -> tuple:
    """(mean_us, min_us) over ``repeats`` timed calls after a warmup.

    The mean stays comparable with earlier committed runs; the min is the
    contention-robust number (shared CI/container CPUs jitter interpret-mode
    timings by 2-3x).
    """
    fn(x).block_until_ready()  # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        times.append(time.perf_counter() - t0)
    return sum(times) / len(times) * 1e6, min(times) * 1e6


def _bench_serve(requests: int, max_new: int, print_fn=print,
                 mesh=None) -> dict:
    """End-to-end served-tokens/s of the fused datapath (continuous batching
    over the qwen2.5-14b smoke KAN-FFN config, mixed prompt lengths).  With
    ``mesh`` the engine serves mesh-sharded (slots/KV on "data", KAN-FFN
    channels on "model")."""
    from repro.configs.registry import smoke_config
    from repro.models.model import init_params
    from repro.serve.engine import Request, ServeEngine

    from repro import runtime

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, slots=2, max_len=64, kan_deploy=True,
                         mesh=mesh)
    rng = jax.random.PRNGKey(1)
    reqs = []
    for rid in range(requests):
        rng, k = jax.random.split(rng)
        plen = 4 + rid % 7  # mixed lengths exercise the prefill buckets
        prompt = jax.random.randint(k, (plen,), 3, cfg.vocab_size).tolist()
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
    pc0 = runtime.cache_stats()
    d0 = dict(runtime.dispatch_counts())
    t0 = time.perf_counter()
    results = engine.run(reqs)
    wall = time.perf_counter() - t0
    total = sum(len(r.output) for r in results)
    stats = engine.compile_stats()
    pc1 = runtime.cache_stats()
    row = {
        "arch": "qwen2.5-14b-kanffn",
        "requests": requests,
        "tokens": total,
        "tokens_per_s": total / wall,
        "prefill_traces": stats["prefill_traces"],
        "decode_traces": stats["decode_traces"],
        "mesh": stats["mesh"],
        # this leg's slice of the process-wide runtime counters (the same
        # series the obs registry exports; docs/observability.md)
        "plan_cache": {k: pc1[k] - pc0[k]
                       for k in ("hits", "misses", "traces")},
        "backend_dispatch": {
            k: v - d0.get(k, 0)
            for k, v in sorted(runtime.dispatch_counts().items())
            if v - d0.get(k, 0)
        },
        "kv": engine.kv_stats(),
    }
    print_fn(
        f"serve,arch={row['arch']},tokens={total},"
        f"tokens_per_s={row['tokens_per_s']:.1f},"
        f"prefill_traces={row['prefill_traces']},"
        f"mesh={None if mesh is None else 'x'.join(map(str, row['mesh']['shape']))}"
    )
    return row


def _bench_sustained(requests: int, max_new: int, print_fn=print,
                     mean_interarrival_s: float = 0.05,
                     arrival_seed: int = 1234) -> dict:
    """Sustained mixed load through the async scheduler, per backend.

    A deterministic Poisson-ish arrival schedule (exponential inter-arrival
    gaps from a fixed-seed generator — identical offsets every run and for
    every engine) drives a MIXED workload: even-rid requests share a
    32-token prefix (the "common system prompt" — 4 full KV blocks at the
    paged legs' 8-token block size) with a short unique tail, odd-rid
    requests are unique mixed-length prompts.  Requests are submitted with
    future ``arrival_s`` offsets, so prompts prefill into free slots
    *between* decode steps of earlier requests exactly as under live
    traffic.

    Each runtime backend (``ref`` / ``pallas`` / ``acim``) serves the
    schedule on a PAGED engine (block pool + prefix cache + chunked
    prefill) after a warmup that compiles every trace the schedule hits, so
    TTFT measures scheduling + prefill, not jit compilation.  Two extra
    legs on the fused backend — the contiguous slab and the paged pool with
    the prefix cache off — isolate what the pool and the cache each buy:
    the shared-prefix half of the workload prefills once under
    ``paged_cache`` and every time under the other two.  Every row records
    the docs/serving.md metrics (TTFT p50/p95, inter-token latency,
    tokens/s, queue-depth trace) plus the KV pool counters (prefix hit
    rate, peak blocks in use, evictions) where applicable.
    """
    import random as _random

    from repro import runtime
    from repro.configs.registry import smoke_config
    from repro.models.model import init_params
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.scheduler import Scheduler

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    params = init_params(jax.random.PRNGKey(0), cfg)
    gen = _random.Random(arrival_seed)
    offsets, t = [], 0.0
    for _ in range(requests):
        offsets.append(t)
        t += gen.expovariate(1.0 / mean_interarrival_s)

    BS = 8                 # paged legs: KV block size (flash KV tile)
    KV_BLOCKS = 48         # pool head-room for cached prefixes + both slots
    SHARED = [9] * (4 * BS)  # the shared system prompt: 4 FULL blocks

    def make_prompts():
        rng = jax.random.PRNGKey(1)
        prompts = []
        for rid in range(requests):
            rng, k = jax.random.split(rng)
            if rid % 2 == 0:  # shared-prefix half: common prompt + 4-tok tail
                prompts.append(
                    SHARED
                    + jax.random.randint(k, (4,), 3, cfg.vocab_size).tolist())
            else:             # unique half, mixed lengths (prefill buckets)
                plen = 4 + rid % 7
                prompts.append(
                    jax.random.randint(k, (plen,), 3, cfg.vocab_size).tolist())
        return prompts

    prompts = make_prompts()

    def serve_one(engine, label):
        # compile outside the timed window.  Contiguous engines need one
        # prefill trace per length bucket the schedule hits; paged engines
        # chunk every prompt into `prefill_chunk`-token pieces (one bucket),
        # so a single full-chunk + partial-chunk warm prompt covers them.
        if engine.paged:
            warm_lens = {BS + 1, 2}
            if getattr(engine, "spec_k", 0):
                # the drafter prefills whole prompts through bucketed pads
                # (not chunks) — warm every bucket the schedule hits, or
                # its compiles land inside the measured window
                warm_lens |= {len(p) for p in prompts}
        else:
            warm_lens = {len(engine._padded_prompt([3] * len(p)))
                         for p in prompts}
        warm = [Request(rid=-1 - i, prompt=[5] * ln, max_new_tokens=2)
                for i, ln in enumerate(sorted(warm_lens))]
        engine.run(warm)
        if engine.paged:
            engine.pool.reset_stats()  # warm prompts are not workload hits
        # counter baselines AFTER warmup: the leg's plan-cache / dispatch
        # slice reflects the measured schedule, not compile warming
        pc0 = runtime.cache_stats()
        d0 = dict(runtime.dispatch_counts())
        # build the request list BEFORE the scheduler: its construction
        # starts the arrival_s timebase, and request construction must not
        # eat into the schedule (submit bumps past offsets to "now")
        reqs = [Request(rid=rid, prompt=p, max_new_tokens=max_new,
                        arrival_s=offsets[rid])
                for rid, p in enumerate(prompts)]
        sched = Scheduler(engine)
        for r in reqs:
            sched.submit(r)
        sched.run_until_idle()
        s = sched.stats()
        kv = s["kv"]
        sp = s["spec"]
        pc1 = runtime.cache_stats()
        row = {
            **label,
            "requests": requests,
            "completed": s["completed"],
            "tokens": s["tokens"],
            "tokens_per_s": s["tokens_per_s"],
            "ttft_p50_s": s["ttft_s"]["p50"],
            "ttft_p95_s": s["ttft_s"]["p95"],
            "itl_p50_s": s["itl_s"]["p50"],
            "itl_p95_s": s["itl_s"]["p95"],
            "queue_depth_max": s["queue_depth"]["max"],
            "queue_depth_mean": s["queue_depth"]["mean"],
            "queue_depth_trace": [[round(ts, 4), d]
                                  for ts, d in sched.queue_depth_trace()],
            "prefix_hit_rate": None if kv is None else kv["prefix_hit_rate"],
            "prefix_hits": None if kv is None else kv["prefix_hits"],
            "prefix_misses": None if kv is None else kv["prefix_misses"],
            "kv_blocks_in_use_peak": (None if kv is None
                                      else kv["blocks_in_use_peak"]),
            "kv_blocks_cached": None if kv is None else kv["blocks_cached"],
            "kv_evictions": None if kv is None else kv["evictions"],
            "kv_allocs": None if kv is None else kv["allocs"],
            # speculative-decode leg fields (spec_k=0 rows: the baseline)
            "spec_k": 0 if sp is None else sp["k"],
            "tokens_per_round": s["tokens_per_round"],
            "accept_rate": None if sp is None else sp["accept_rate"],
            "draft_ms": (None if sp is None or sp["draft_s"]["p50"] is None
                         else sp["draft_s"]["p50"] * 1e3),
            "verify_ms": (None if sp is None or sp["verify_s"]["p50"] is None
                          else sp["verify_s"]["p50"] * 1e3),
            "plan_cache": {k: pc1[k] - pc0[k]
                           for k in ("hits", "misses", "traces")},
            "backend_dispatch": {
                k: v - d0.get(k, 0)
                for k, v in sorted(runtime.dispatch_counts().items())
                if v - d0.get(k, 0)
            },
        }
        print_fn(
            f"sustained,backend={row['backend']},kv={row['kv']},"
            f"spec_k={row['spec_k']},"
            f"tokens={row['tokens']},tokens_per_s={row['tokens_per_s']:.1f},"
            f"ttft_p50_ms={row['ttft_p50_s'] * 1e3:.1f},"
            f"ttft_p95_ms={row['ttft_p95_s'] * 1e3:.1f},"
            f"qdepth_max={row['queue_depth_max']}"
            + ("" if kv is None else
               f",hit_rate={row['prefix_hit_rate']:.2f},"
               f"kv_peak={row['kv_blocks_in_use_peak']}")
            + ("" if sp is None or row["accept_rate"] is None else
               f",accept_rate={row['accept_rate']:.2f},"
               f"tok_per_round={row['tokens_per_round']:.2f}")
        )
        return row

    paged_kw = dict(kv_block_size=BS, kv_blocks=KV_BLOCKS, prefill_chunk=BS)
    SPEC_K = 2  # speculative legs: k drafted tokens per slot per round
    rows = []
    for backend in ("ref", "pallas", "acim"):
        engine = ServeEngine(params, cfg, slots=2, max_len=64,
                             kan_deploy=True, kan_backend=backend,
                             prefix_cache=True, **paged_kw)
        rows.append(serve_one(engine, {"backend": backend,
                                       "kv": "paged_cache"}))
    # speculative-decode legs: same schedule, same paged engine, a cheap
    # KAN drafter (default halved grid) proposing SPEC_K tokens per round
    # with one batched verify pass — greedy streams stay bit-identical, so
    # tokens/tokens_per_s compare directly against the spec_k=0 rows above
    for backend in ("ref", "pallas", "acim"):
        engine = ServeEngine(params, cfg, slots=2, max_len=64,
                             kan_deploy=True, kan_backend=backend,
                             prefix_cache=True, spec_decode=SPEC_K,
                             **paged_kw)
        rows.append(serve_one(engine, {"backend": backend,
                                       "kv": "paged_cache"}))
    # what did the pool / the prefix cache each buy? — same schedule on the
    # fused backend with (a) the contiguous slab, (b) the pool, cache off
    for kv_mode, kw in (("contiguous", {}),
                        ("paged_nocache", dict(prefix_cache=False,
                                               **paged_kw))):
        engine = ServeEngine(params, cfg, slots=2, max_len=64,
                             kan_deploy=True, kan_backend="pallas", **kw)
        rows.append(serve_one(engine, {"backend": "pallas", "kv": kv_mode}))

    def _pallas(kv_mode):
        return next(r for r in rows
                    if r["backend"] == "pallas" and r["kv"] == kv_mode
                    and r["spec_k"] == 0)

    summary = {  # the cache-on-vs-off headline (acceptance: on <= off p95)
        "ttft_p95_contiguous_s": _pallas("contiguous")["ttft_p95_s"],
        "ttft_p95_paged_nocache_s": _pallas("paged_nocache")["ttft_p95_s"],
        "ttft_p95_paged_cache_s": _pallas("paged_cache")["ttft_p95_s"],
        "prefix_hit_rate": _pallas("paged_cache")["prefix_hit_rate"],
    }
    print_fn(
        f"sustained,kv_summary,"
        f"ttft_p95_contiguous_ms={summary['ttft_p95_contiguous_s'] * 1e3:.1f},"
        f"ttft_p95_nocache_ms={summary['ttft_p95_paged_nocache_s'] * 1e3:.1f},"
        f"ttft_p95_cache_ms={summary['ttft_p95_paged_cache_s'] * 1e3:.1f},"
        f"hit_rate={summary['prefix_hit_rate']:.2f}"
    )

    def _leg(backend, spec_k):
        return next(r for r in rows
                    if r["backend"] == backend and r["kv"] == "paged_cache"
                    and r["spec_k"] == spec_k)

    spec_summary = {  # the spec-on-vs-off headline per backend
        "k": SPEC_K,
        "per_backend": {
            b: {
                "tokens_per_s_off": _leg(b, 0)["tokens_per_s"],
                "tokens_per_s_on": _leg(b, SPEC_K)["tokens_per_s"],
                "accept_rate": _leg(b, SPEC_K)["accept_rate"],
                "tokens_per_round": _leg(b, SPEC_K)["tokens_per_round"],
                "draft_ms": _leg(b, SPEC_K)["draft_ms"],
                "verify_ms": _leg(b, SPEC_K)["verify_ms"],
            }
            for b in ("ref", "pallas", "acim")
        },
    }
    for b, d in spec_summary["per_backend"].items():
        print_fn(
            f"sustained,spec_summary,backend={b},k={SPEC_K},"
            f"tok_s_off={d['tokens_per_s_off']:.1f},"
            f"tok_s_on={d['tokens_per_s_on']:.1f},"
            + (f"accept_rate={d['accept_rate']:.2f},"
               if d["accept_rate"] is not None else "accept_rate=n/a,")
            + f"tok_per_round={d['tokens_per_round']:.2f}"
        )
    return {
        "arch": "qwen2.5-14b-kanffn",
        "slots": 2,
        "arrival_seed": arrival_seed,
        "mean_interarrival_s": mean_interarrival_s,
        "arrival_offsets_s": offsets,
        "workload": {
            "requests": requests,
            "shared_prefix_tokens": len(SHARED),
            "shared_prefix_share": 0.5,
            "unique_plen_range": [4, 10],
        },
        "kv_block_size": BS,
        "kv_blocks": KV_BLOCKS,
        "prefill_chunk": BS,
        "spec_k": SPEC_K,
        "rows": rows,
        "kv_summary": summary,
        "spec_summary": spec_summary,
    }


def _bench_attention(repeats: int, print_fn=print) -> dict:
    """Per-step decode latency per ATTENTION backend — the "every FLOP-heavy
    op fused" datapoint.

    Times the engine's compiled decode step (all slots advance one token) on
    the qwen2.5-14b smoke KAN-FFN config with ``kan_deploy=True``, once per
    registered attention backend: with "flash" both the attention and the
    KAN-FFN halves of every block execute as fused Pallas kernels
    (``all_fused`` in the row), with "ref" attention stays on the chunked
    XLA composition.  A full-sequence prefill-shape SDPA microbench (ref vs
    flash on the same GQA geometry) rides along.  Off-TPU both kernels run
    in interpret mode — these numbers validate plumbing, not TPU perf.
    """
    from repro.configs.registry import smoke_config
    from repro.models import layers as L
    from repro.models.model import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    params = init_params(jax.random.PRNGKey(0), cfg)

    # prefill-shape microbench: full-sequence SDPA, per backend
    b, s, d = 2, 128, cfg.head_dim
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (b, s, cfg.num_heads, d), jnp.float32)
    k = jax.random.normal(key, (b, s, cfg.num_kv_heads, d), jnp.float32)
    v = jax.random.normal(key, (b, s, cfg.num_kv_heads, d), jnp.float32)
    prefill_rows = []
    for backend in runtime.available_attn_backends():
        fn = jax.jit(
            lambda q, be=backend: L._sdpa(q, k, v, cfg, "global", backend=be)
        )
        mean_us, min_us = _time_fn(fn, q, repeats)
        prefill_rows.append({
            "attn_backend": backend, "batch": b, "seq": s,
            "heads": cfg.num_heads, "kv_heads": cfg.num_kv_heads,
            "sdpa_us": mean_us, "sdpa_min_us": min_us,
        })
        print_fn(f"attention,leg=prefill_sdpa,attn_backend={backend},"
                 f"seq={s},us={mean_us:.0f}")

    # decode-step latency: continuous-batching engine, fused KAN datapath
    decode_rows = []
    for backend in runtime.available_attn_backends():
        engine = ServeEngine(params, cfg, slots=2, max_len=64,
                             kan_deploy=True, attn_backend=backend)
        for rid in range(engine.slots):
            engine._admit(Request(rid=rid, prompt=[5, 6, 7, 8],
                                  max_new_tokens=4))
        pos = jnp.asarray(engine.pos)

        def step(tok, eng=engine, pos=pos):
            with runtime.use_backend(eng.kan_backend):
                logits, _ = eng._decode(eng.params, eng.cache, tok, pos)
            return logits

        token = jnp.zeros((engine.slots,), jnp.int32)
        mean_us, min_us = _time_fn(step, token, repeats)
        row = {
            "attn_backend": engine.attn_backend,
            "kan_backend": runtime.resolve_backend(engine.kan_backend),
            "all_fused": engine.attn_backend == "flash",
            "slots": engine.slots,
            "decode_step_us": mean_us,
            "decode_step_min_us": min_us,
            "tokens_per_s": engine.slots / (min_us * 1e-6),
        }
        decode_rows.append(row)
        print_fn(
            f"attention,leg=decode_step,attn_backend={row['attn_backend']},"
            f"kan_backend={row['kan_backend']},"
            f"all_fused={int(row['all_fused'])},"
            f"decode_step_us={mean_us:.0f},tok_s={row['tokens_per_s']:.0f}"
        )
    return {"arch": "qwen2.5-14b-kanffn", "prefill": prefill_rows,
            "decode": decode_rows}


def _bench_sharded(batch: int, repeats: int, serve_requests: int,
                   serve_max_new: int, print_fn=print) -> dict:
    """Mesh-sharded legs: the perf trajectory's scaling axis.

    Times the fused executor on a data-only mesh over every host device and
    (when >= 2 devices) a data x model mesh, on the FFN-width config whose
    output channels actually shard, plus a sharded-engine served-tokens/s
    leg.  Mesh shape and device count ride in every row so BENCH json
    captures scaling, not just single-device latency.  On 1 device this
    degenerates to a 1x1 mesh — the overhead-of-shard_map datapoint.
    """
    from repro.launch.mesh import make_local_mesh

    n = len(jax.devices())
    interpret = default_interpret()
    name, dims, grid = CONFIGS[2]  # ffn width: op=128 per layer, shardable
    kspec = KANSpec(dims=dims, grid_size=grid)
    key = jax.random.PRNGKey(0)
    qparams = quantize_kan_network(init_kan_network(key, kspec), kspec)
    dep = deploy_kan_network(qparams, kspec, batch=batch)
    x = jax.random.uniform(key, (batch, dims[0]), minval=-1.0, maxval=1.0)

    legs = [("data", make_local_mesh(n, 1))]
    if n >= 2:
        legs.append(("data_x_model", make_local_mesh(n // 2, 2)))
    rows = []
    for leg, mesh in legs:
        fn = lambda x, m=mesh: kan_network_deploy_apply(
            dep, x, interpret=interpret, backend="pallas", mesh=m
        )
        mean_us, min_us = _time_fn(fn, x, repeats)
        row = {
            "name": name,
            "leg": leg,
            "mesh_axes": list(mesh.axis_names),
            "mesh_shape": [int(s) for s in mesh.devices.shape],
            "device_count": n,
            "batch": batch,
            "fused_sharded_us": mean_us,
            "fused_sharded_min_us": min_us,
            "fused_sharded_tokens_per_s": batch / (min_us * 1e-6),
        }
        rows.append(row)
        print_fn(
            f"sharded,{name},leg={leg},"
            f"mesh={'x'.join(map(str, row['mesh_shape']))},"
            f"devices={n},fused_sharded_us={mean_us:.0f},"
            f"tok_s={row['fused_sharded_tokens_per_s']:.0f}"
        )
    serve = _bench_serve(serve_requests, serve_max_new, print_fn=print_fn,
                         mesh=legs[-1][1])
    return {"device_count": n, "rows": rows, "serve": serve}


def _bench_quant_frontier(print_fn=print, epochs: int = 20) -> dict:
    """KANtize-style accuracy-vs-bits frontier on the paper's KAN1 geometry.

    One small float base network is trained once on the knot surrogate,
    then every per-layer bit allocation in the sweep is quantized/deployed
    from it (mixed-precision ``KANSpec.n_bits`` tuples; <=4-bit layers run
    int4-packed through the fused kernel) and scored exactly like the
    co-design search: accuracy on the ``acim`` backend with the measured
    22nm non-idealities, cost via ``kan_cost`` with bit-dependent cell
    area/energy.  Rows carry a ``pareto`` flag on (energy_pj, accuracy) —
    the sub-8-bit allocations trade accuracy for energy, and at least one
    lands on the front (the (4, 4) corner is the energy argmin by
    construction).
    """
    from repro import tune

    task = tune.make_knot_task(n_train=2048, n_val=256, epochs=epochs,
                               seed=0, base_grid=5, calib_n=128)
    allocations = ((8, 8), (8, 4), (4, 8), (4, 4))
    points = []
    for alloc in allocations:
        cand = tune.Candidate(grid_size=5, order=3, n_bits=8,
                              layer_bits=alloc)
        metrics = tune.evaluate_candidate(task, cand, acim_seeds=2)
        points.append(tune.EvaluatedPoint(candidate=cand, metrics=metrics))
    front = tune.pareto_front(points, ("energy_pj", "accuracy"))
    rows = []
    for p in points:
        row = {
            "layer_bits": list(p.candidate.layer_bits),
            "accuracy": p.metrics["accuracy"],
            "energy_pj": p.metrics["energy_pj"],
            "area_mm2": p.metrics["area_mm2"],
            "latency_ns": p.metrics["latency_ns"],
            "sub8": any(b < 8 for b in p.candidate.layer_bits),
            "pareto": any(q is p for q in front),
        }
        rows.append(row)
        print_fn(
            f"quant_frontier,bits={'/'.join(map(str, row['layer_bits']))},"
            f"accuracy={row['accuracy']:.3f},"
            f"energy_pj={row['energy_pj']:.1f},"
            f"area_mm2={row['area_mm2']:.4f},"
            f"pareto={int(row['pareto'])}"
        )
    assert any(r["pareto"] and r["sub8"] for r in rows), rows
    return {
        "dims": list(task.dims),
        "grid": 5,
        "objectives": ["energy_pj", "accuracy"],
        "rows": rows,
    }


def run(batch: int = 128, repeats: int = 10, serve_requests: int = 4,
        serve_max_new: int = 8, sustained_requests: int = 60,
        tuned: bool = False, tile_candidates: int = 10,
        print_fn=print) -> dict:
    interpret = default_interpret()
    runtime.reset_cache()
    rows = []
    for name, dims, grid in CONFIGS:
        kspec = KANSpec(dims=dims, grid_size=grid)
        key = jax.random.PRNGKey(0)
        params = init_kan_network(key, kspec)
        qparams = quantize_kan_network(params, kspec)
        dep = deploy_kan_network(qparams, kspec, batch=batch)
        x = jax.random.uniform(key, (batch, dims[0]), minval=-1.0, maxval=1.0)

        float_fn = jax.jit(lambda x, ks=kspec, p=params: kan_network_apply(p, x, ks))
        ref_fn = lambda x, d=dep: kan_network_deploy_apply(
            d, x, interpret=interpret, backend="ref"
        )
        fused_fn = lambda x, d=dep: kan_network_deploy_apply(
            d, x, interpret=interpret, backend="pallas"
        )
        acim_fn = lambda x, d=dep: kan_network_deploy_apply(
            d, x, interpret=interpret, backend="acim", cim=ACIM_CFG,
            key=jax.random.PRNGKey(0),
        )

        row = {"name": name, "dims": list(dims), "grid": grid, "batch": batch,
               "pallas_interpret": interpret}
        for label, fn in (("float", float_fn), ("quant_ref", ref_fn),
                          ("fused_pallas", fused_fn), ("acim", acim_fn)):
            mean_us, min_us = _time_fn(fn, x, repeats)
            row[f"{label}_us"] = mean_us
            row[f"{label}_min_us"] = min_us
        row["fused_tokens_per_s"] = batch / (row["fused_pallas_min_us"] * 1e-6)
        row["acim_tokens_per_s"] = batch / (row["acim_min_us"] * 1e-6)
        err = float(jnp.abs(fused_fn(x) - ref_fn(x)).max())
        row["fused_vs_ref_max_err"] = err
        row["acim_vs_fused_max_err"] = float(
            jnp.abs(acim_fn(x) - fused_fn(x)).max()
        )
        if tuned:
            # heuristic-plan vs tuned-plan fused execution.  The tile tuner
            # registers its winner with the plan cache (warm-traced inside
            # the tuner), so the same fused_fn transparently runs the tuned
            # geometry afterwards; off-TPU the tuner ranks by its
            # deterministic proxy and typically keeps the heuristic.
            from repro.tune import tune_tiles

            tile = tune_tiles(dep, batch=batch, interpret=interpret,
                              max_candidates=tile_candidates)
            mean_us, min_us = _time_fn(fused_fn, x, repeats)
            row["fused_tuned_us"] = mean_us
            row["fused_tuned_min_us"] = min_us
            row["tile_mode"] = tile.mode
            row["tile_trials"] = len(tile.trials)
            row["tile_tuned"] = tile.tuned
            row["tile_overrides"] = (
                None if tile.chosen_overrides is None
                else [list(t) for t in tile.chosen_overrides]
            )
            # exactness is a tuner invariant; assert it held end to end
            err_t = float(jnp.abs(fused_fn(x) - ref_fn(x)).max())
            assert err_t == err, (err_t, err)
            runtime.PLAN_CACHE.set_tile_overrides(
                tuple(dep.dims), tuple(dep.specs), dep.residual_raw, None
            )
        rows.append(row)
        msg = (
            f"{name},float_us={row['float_us']:.0f},"
            f"quant_ref_us={row['quant_ref_us']:.0f},"
            f"fused_pallas_us={row['fused_pallas_us']:.0f},"
            f"acim_us={row['acim_us']:.0f},"
            f"fused_tok_s={row['fused_tokens_per_s']:.0f},"
            f"err={err:.2e}"
        )
        if tuned:
            msg += (f",fused_tuned_us={row['fused_tuned_us']:.0f},"
                    f"tile_mode={row['tile_mode']},"
                    f"tile_tuned={int(row['tile_tuned'])}")
        print_fn(msg)
    quant_frontier = _bench_quant_frontier(print_fn=print_fn)
    serve = _bench_serve(serve_requests, serve_max_new, print_fn=print_fn)
    sustained = _bench_sustained(sustained_requests, serve_max_new,
                                 print_fn=print_fn)
    attention = _bench_attention(repeats, print_fn=print_fn)
    sharded = _bench_sharded(batch, repeats, serve_requests, serve_max_new,
                             print_fn=print_fn)
    cache = runtime.cache_stats()  # after the serve legs: they share the cache
    print_fn(f"plan_cache,{cache}")
    return {
        "benchmark": "kan_pipeline",
        "backend": jax.default_backend(),
        "pallas_interpret": interpret,
        "device_count": len(jax.devices()),
        "rows": rows,
        "quant_frontier": quant_frontier,
        "serve": serve,
        "sustained": sustained,
        "attention": attention,
        "sharded": sharded,
        "plan_cache": cache,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: small batch/repeats, short serve leg")
    ap.add_argument("--tuned", action="store_true",
                    help="add the heuristic-vs-tuned tile-plan leg "
                         "(repro.tune.tiles) to every config")
    ap.add_argument("--out", default="BENCH_kan_pipeline.json")
    args = ap.parse_args()
    if args.smoke:
        result = run(batch=32, repeats=2, serve_requests=2, serve_max_new=4,
                     sustained_requests=6, tuned=args.tuned,
                     tile_candidates=6)
    else:
        result = run(batch=args.batch, repeats=args.repeats,
                     tuned=args.tuned)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
