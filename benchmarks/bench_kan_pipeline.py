"""Benchmark the multi-layer KAN inference paths; seeds the perf trajectory.

Three executors over the same quantized network:

  * ``float``      — kan_network_apply float path (Cox-de Boor basis, f32)
  * ``quant_ref``  — layered jnp quantized path (backend="ref"): per-layer
                     quantize / SH-LUT / banded matmul with f32 round-trips
                     between layers
  * ``fused``      — the fused Pallas pipeline (backend="pallas"): every
                     layer in the kan_spline kernel, inter-layer
                     requantization fused, int codes across boundaries

at the paper's KAN1 (17,1,14 / G=5) and KAN2 (G=68) edge configs and one
transformer-FFN width (the qwen2.5-14b smoke KAN-FFN geometry).  Off-TPU the
Pallas path runs in interpret mode — those numbers validate plumbing, not
TPU perf (same caveat as benchmarks/run.py's kernel microbench).

    PYTHONPATH=src python benchmarks/bench_kan_pipeline.py --out BENCH_kan_pipeline.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.kan_layer import KANSpec, init_kan_network, kan_network_apply
from repro.core.kan_network_deploy import (
    default_interpret,
    deploy_kan_network,
    kan_network_deploy_apply,
    quantize_kan_network,
)

CONFIGS = [
    # (name, dims, grid)  — KAN1/KAN2 are the paper's edge nets (§4);
    # ffn_width is the LM deployment surface (models/layers KAN-FFN smoke).
    ("kan1_17_1_14_g5", (17, 1, 14), 5),
    ("kan2_17_1_14_g68", (17, 1, 14), 68),
    ("ffn_64_128_64_g8", (64, 128, 64), 8),
]


def _time_fn(fn, x, repeats: int) -> float:
    fn(x).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn(x).block_until_ready()
    return (time.perf_counter() - t0) / repeats * 1e6


def run(batch: int = 128, repeats: int = 10, print_fn=print) -> dict:
    interpret = default_interpret()
    rows = []
    for name, dims, grid in CONFIGS:
        kspec = KANSpec(dims=dims, grid_size=grid)
        key = jax.random.PRNGKey(0)
        params = init_kan_network(key, kspec)
        qparams = quantize_kan_network(params, kspec)
        dep = deploy_kan_network(qparams, kspec, batch=batch)
        x = jax.random.uniform(key, (batch, dims[0]), minval=-1.0, maxval=1.0)

        float_fn = jax.jit(lambda x, ks=kspec, p=params: kan_network_apply(p, x, ks))
        ref_fn = jax.jit(
            lambda x, ks=kspec, q=qparams: kan_network_apply(
                None, x, ks, quantized=True, qparams_list=q
            )
        )
        fused_fn = lambda x, d=dep: kan_network_deploy_apply(
            d, x, interpret=interpret
        )

        row = {
            "name": name,
            "dims": list(dims),
            "grid": grid,
            "batch": batch,
            "float_us": _time_fn(float_fn, x, repeats),
            "quant_ref_us": _time_fn(ref_fn, x, repeats),
            "fused_pallas_us": _time_fn(fused_fn, x, repeats),
            "pallas_interpret": interpret,
        }
        err = float(
            jnp.abs(fused_fn(x) - ref_fn(x)).max()
        )
        row["fused_vs_ref_max_err"] = err
        rows.append(row)
        print_fn(
            f"{name},float_us={row['float_us']:.0f},"
            f"quant_ref_us={row['quant_ref_us']:.0f},"
            f"fused_pallas_us={row['fused_pallas_us']:.0f},"
            f"err={err:.2e}"
        )
    return {
        "benchmark": "kan_pipeline",
        "backend": jax.default_backend(),
        "pallas_interpret": interpret,
        "rows": rows,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=10)
    ap.add_argument("--out", default="BENCH_kan_pipeline.json")
    args = ap.parse_args()
    result = run(batch=args.batch, repeats=args.repeats)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
