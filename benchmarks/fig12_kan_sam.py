"""Fig. 12: KAN-SAM accuracy protection vs RRAM array size.

Protocol (paper §4.C): four KANs (17x1x14) with G = 7/15/30/60 mapped to
arrays of 128/256/512/1024 rows; MAC errors injected from the IR-drop +
partial-sum model calibrated to the TSMC 22nm measurements trend; baseline
maps c' rows in natural order, KAN-SAM orders rows by activation
probability.  Reported: accuracy degradation from the software (error-free
quantized) baseline, and the SAM protection ratio = deg_base / deg_sam.

Paper: protection ratio grows 3.9x -> 4.63x as arrays scale 128 -> 1024.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cim import CIMConfig
from repro.core.kan_layer import KANSpec
from repro.core.neurosim import (
    evaluate_accuracy,
    evaluate_accuracy_cim,
    train_kan,
)
from repro.data.knot import make_knot_dataset

PAPER_RATIO_128 = 3.9
PAPER_RATIO_1024 = 4.63

SWEEP = [(7, 128), (15, 256), (30, 512), (60, 1024)]


def run(print_fn=print, fast: bool = False, seed: int = 0) -> dict:
    n_train = 8192 if fast else 16384
    epochs = 100 if fast else 180
    trials = 2 if fast else 3
    xt, yt, xv, yv = make_knot_dataset(n_train, 2048, seed=seed, label_noise=0.04)
    steps_per_epoch = max(1, n_train // 2048)

    def sched(step):
        t = jnp.minimum(step / (epochs * steps_per_epoch * 0.9), 1.0)
        return 1.5e-2 * 0.95 * (0.5 * (1 + jnp.cos(jnp.pi * t))) + 1e-3

    rows = []
    for g, array in SWEEP:
        kspec = KANSpec(dims=(17, 1, 14), grid_size=g)
        params, _ = train_kan(kspec, xt, yt, xv, yv, epochs=epochs,
                              batch_size=2048, lr=sched, seed=seed)
        sw_acc = evaluate_accuracy(params, xv, yv, kspec)
        cim_cfg = CIMConfig(array_rows=array, adc_bits=10, ir_gamma=0.06,
                            sigma_ps_ref=0.05)
        accs = {"base": [], "sam": []}
        for t in range(trials):
            key = jax.random.PRNGKey(1000 + t)
            accs["base"].append(evaluate_accuracy_cim(
                params, xv, yv, kspec, cim_cfg, key, use_sam=False))
            accs["sam"].append(evaluate_accuracy_cim(
                params, xv, yv, kspec, cim_cfg, key, use_sam=True,
                calib_x=xt[:2048]))
        deg_base = sw_acc - float(np.mean(accs["base"]))
        deg_sam = sw_acc - float(np.mean(accs["sam"]))
        ratio = deg_base / max(deg_sam, 3e-3)  # floor: stat. noise of 2k eval
        rows.append({
            "G": g, "array": array, "sw_acc": sw_acc,
            "acc_base": float(np.mean(accs["base"])),
            "acc_sam": float(np.mean(accs["sam"])),
            "deg_base": deg_base, "deg_sam": deg_sam, "ratio": ratio,
        })

    print_fn("fig12: KAN-SAM accuracy protection vs array size")
    print_fn("G,array,sw_acc,acc_base,acc_sam,deg_base,deg_sam,protection_ratio")
    for r in rows:
        print_fn(
            f"{r['G']},{r['array']},{r['sw_acc']:.3f},{r['acc_base']:.3f},"
            f"{r['acc_sam']:.3f},{r['deg_base']:.3f},{r['deg_sam']:.3f},"
            f"{r['ratio']:.2f}"
        )
    print_fn(f"paper_ratio_trend,{PAPER_RATIO_128}->{PAPER_RATIO_1024} (128->1024)")
    return {"rows": rows}


if __name__ == "__main__":
    run()
