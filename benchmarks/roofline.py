"""§Roofline: aggregate dry-run reports into the per-cell roofline table.

Reads reports/dryrun/*.json (written by repro.launch.dryrun), adds
MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) and the useful-compute ratio,
and emits the EXPERIMENTS.md §Roofline table.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS, SHAPES, get_config
from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS


def param_counts(cfg: ModelConfig) -> tuple:
    """(total params N, activated params N_active) — analytic."""
    d, v = cfg.d_model, cfg.vocab_size
    n_embed = v * d * (1 if cfg.tie_embeddings else 2)
    per_layer_attn = 0
    if cfg.num_heads:
        hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        per_layer_attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d

    def ffn_params():
        if cfg.ffn_kind == "swiglu":
            return 3 * d * cfg.d_ff
        if cfg.ffn_kind == "gelu":
            return 2 * d * cfg.d_ff
        if cfg.ffn_kind == "kan":
            nb = cfg.kan_grid + cfg.kan_order
            h = cfg.kan_d_hidden or max(1, cfg.d_ff // nb)
            return d * (nb + 1) * h + h * (nb + 1) * d
        return 0

    total = n_embed
    active = n_embed
    for kind in cfg.layer_kinds:
        if kind in ("global", "local", "bidir"):
            total += per_layer_attn
            active += per_layer_attn
            if cfg.num_experts:
                e_params = cfg.num_experts * 3 * d * cfg.d_ff
                total += e_params + d * cfg.num_experts
                active += cfg.num_experts_per_tok * 3 * d * cfg.d_ff
            else:
                total += ffn_params()
                active += ffn_params()
        elif kind == "rglru":
            w = cfg.rnn_width or d
            r = 2 * d * w + 2 * w * w + w * d
            total += r + ffn_params()
            active += r + ffn_params()
        elif kind == "ssm":
            din = cfg.ssm_expand * d
            nh = din // cfg.ssm_head_dim
            r = d * (2 * din + 2 * cfg.ssm_state + nh) + din * d
            total += r
            active += r
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (per_layer_attn + ffn_params())
        active += cfg.encoder_layers * (per_layer_attn + ffn_params())
    return total, active


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """6·N_active·D for train; 2·N_active·D for inference-style cells."""
    sh = SHAPES[shape_name]
    _, active = param_counts(cfg)
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * active * tokens
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * active * tokens
    return 2.0 * active * sh["global_batch"]  # decode: one token per seq


def load_reports(directory: str = "reports/dryrun") -> list:
    out = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def table(reports: list, print_fn=print):
    print_fn(
        "arch,shape,mesh,flops/dev,peak_GiB/dev,coll_MiB/dev,"
        "compute_s,memory_s,collective_s,dominant,roofline_frac,"
        "model_flops,useful_ratio"
    )
    rows = []
    for r in reports:
        cfg = get_config(r["arch"])
        mf = model_flops(cfg, r["shape"])
        devs = r["devices"]
        total_hlo = r["flops_per_dev"] * devs
        useful = mf / total_hlo if total_hlo else 0.0
        rl = r["roofline"]
        mesh_tag = "x".join(str(m) for m in r["mesh"])
        row = dict(r, model_flops=mf, useful_ratio=useful)
        rows.append(row)
        print_fn(
            f"{r['arch']},{r['shape']},{mesh_tag},{r['flops_per_dev']:.3e},"
            f"{r['memory'].get('peak_bytes', 0)/2**30:.2f},"
            f"{r['collectives']['total']/2**20:.1f},"
            f"{rl['compute_s']:.4f},{rl['memory_s']:.4f},{rl['collective_s']:.4f},"
            f"{rl['dominant']},{rl['roofline_fraction']:.3f},"
            f"{mf:.3e},{useful:.3f}"
        )
    return rows


def run(print_fn=print, directory: str = "reports/dryrun"):
    reports = load_reports(directory)
    if not reports:
        print_fn("roofline: no dry-run reports found (run repro.launch.dryrun --all)")
        return {"rows": []}
    rows = table(reports, print_fn)
    return {"rows": rows}


if __name__ == "__main__":
    run()
