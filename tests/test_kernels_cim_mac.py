"""Pallas cim_mac kernel vs pure-jnp oracle + cim.py driver agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cim import CIMConfig, cim_matmul
from repro.kernels.cim_mac.ops import cim_mac
from repro.kernels.cim_mac.ref import cim_mac_ref


CASES = [
    (16, 300, 20, 128),
    (8, 1024, 14, 256),
    (130, 136, 1, 128),   # the paper's KAN layer-1 geometry
    (4, 50, 3, 512),
    (32, 2048, 64, 1024),
]


def _assert_adc_close(out, ref, w_tiled, adc_bits, x_max=255.0,
                      tight_frac=0.95):
    """ADC-aware agreement: the kernel and the oracle quantize bit-identical
    MATH, but ulp-level float reassociation (tiling/padding changes the gemm
    reduction order) can flip jnp.round by one ADC LSB per array partial.
    Contract: every element within the worst-case per-array LSB flip, and the
    overwhelming majority bit-tight."""
    fs = x_max * np.abs(np.asarray(w_tiled)).sum(axis=1)       # (A, C)
    lsb = 2.0 * fs / (2**adc_bits)
    allow = 1.01 * lsb.sum(axis=0)                             # (C,)
    diff = np.abs(np.asarray(out) - np.asarray(ref))
    assert (diff <= allow[None, :]).all(), diff.max()
    tight = diff <= 1e-5 * np.abs(np.asarray(ref)) + 1e-3
    assert tight.mean() >= tight_frac, tight.mean()


@pytest.mark.parametrize("case", CASES)
def test_cim_mac_matches_cim_py(case):
    B, R, C, rows = case
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (B, R), minval=0, maxval=255.0)
    w = jax.random.randint(key, (R, C), -127, 128).astype(jnp.float32)
    out = cim_mac(x, w, array_rows=rows, ir_scale=0.04 * (rows / 128) ** 0.5,
                  adc_bits=10, x_max=255.0, interpret=True)
    cfg = CIMConfig(array_rows=rows, adc_bits=10, ir_gamma=0.04, deterministic=True)
    ref = cim_matmul(x, w, cfg, key)
    n_arrays = -(-R // rows)
    w_t = np.pad(np.asarray(w), ((0, n_arrays * rows - R), (0, 0))) \
        .reshape(n_arrays, rows, C)
    _assert_adc_close(out, ref, w_t, adc_bits=10)


def test_cim_mac_tiled_ref_identity():
    """kernel == 3-D oracle on pre-tiled operands (no padding path)."""
    key = jax.random.PRNGKey(1)
    B, A, R, C = 16, 3, 128, 128
    x = jax.random.uniform(key, (B, A, R), maxval=255.0)
    w = jax.random.randint(key, (A, R, C), -127, 128).astype(jnp.float32)
    load = jax.random.uniform(key, (A, C))
    fs = 255.0 * jnp.abs(w).sum(axis=1)
    from repro.kernels.cim_mac.kernel import cim_mac_pallas

    out = cim_mac_pallas(x, w, load, fs, ir_scale=0.05, adc_bits=8,
                         block_b=8, block_c=128, interpret=True)
    ref = cim_mac_ref(x, w, load, fs, ir_scale=0.05, adc_bits=8)
    # same ADC-LSB contract as above (fs is explicit here)
    allow = 1.01 * (2.0 * np.asarray(fs) / 2**8).sum(axis=0)
    diff = np.abs(np.asarray(out) - np.asarray(ref))
    assert (diff <= allow[None, :]).all(), diff.max()
    tight = diff <= 1e-6 * np.abs(np.asarray(ref)) + 1e-3
    assert tight.mean() >= 0.95, tight.mean()


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 32),
    r=st.integers(1, 400),
    c=st.integers(1, 48),
    rows=st.sampled_from([128, 256]),
    adc=st.sampled_from([6, 8, 12]),
    seed=st.integers(0, 1000),
)
def test_cim_mac_property(b, r, c, rows, adc, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (b, r), maxval=255.0)
    w = jax.random.randint(key, (r, c), -127, 128).astype(jnp.float32)
    out = cim_mac(x, w, array_rows=rows, ir_scale=0.03, adc_bits=adc,
                  x_max=255.0, interpret=True)
    cfg = CIMConfig(array_rows=rows, adc_bits=adc,
                    ir_gamma=0.03 / (rows / 128) ** 0.5,
                    deterministic=True)
    ref = cim_matmul(x, w, cfg, key)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=np.abs(np.asarray(ref)).max() * 1e-5 + 1e-3)


def test_zero_ir_high_adc_is_exact_matmul():
    key = jax.random.PRNGKey(2)
    x = jax.random.uniform(key, (8, 256), maxval=255.0)
    w = jax.random.randint(key, (256, 16), -127, 128).astype(jnp.float32)
    out = cim_mac(x, w, array_rows=128, ir_scale=0.0, adc_bits=24,
                  x_max=255.0, interpret=True)
    # 24-bit ADC rounding on the worst-case full-scale leaves ~2e-4 rel
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), rtol=1e-3)
