"""Cost model: paper-table agreement bounds; NeuroSim search invariants."""

import numpy as np
import pytest

from repro.core.asp_quant import ASPQuantSpec
from repro.core.costmodel import (
    accelerator_cost,
    bx_path_asp,
    bx_path_conventional,
    input_generator_cost,
    kan_accelerator,
    mlp_accelerator,
)
from repro.core.neurosim import HardwareConstraints, check_constraints, search_max_grid
from repro.core.tmdv import PURE_PWM, PURE_VOLTAGE, TMDVConfig


def test_fig10_ratios_in_paper_band():
    ra, re_ = [], []
    for g in (8, 16, 32, 64):
        s = ASPQuantSpec(grid_size=g, order=3, n_bits=8, lo=0.0, hi=1.0)
        c, a = bx_path_conventional(s), bx_path_asp(s)
        ra.append(c["area_um2"] / a["area_um2"])
        re_.append(c["energy_pj"] / a["energy_pj"])
    assert 30 < np.mean(ra) < 55, np.mean(ra)      # paper: 40.14x
    assert 3.5 < np.mean(re_) < 8, np.mean(re_)    # paper: 5.59x
    assert ra == sorted(ra)                        # improvement grows with G


def test_fig11_ratios_in_paper_band():
    v = input_generator_cost(PURE_VOLTAGE(6))
    p = input_generator_cost(PURE_PWM(6))
    t = input_generator_cost(TMDVConfig(total_bits=6, voltage_bits=3))
    assert 1.7 < v["area_um2"] / t["area_um2"] < 2.3        # 1.96
    assert 9 < v["power_uw"] / t["power_uw"] < 15           # 11.9
    assert p["latency_ns"] / t["latency_ns"] == 8.0         # 8x
    assert 0.9 < p["area_um2"] / t["area_um2"] < 1.3        # 1.07
    assert 2.3 < t["fom"] / v["fom"] < 3.8                  # 3x
    assert 3.2 < t["fom"] / p["fom"] < 5.2                  # 4.1x


def test_fig13_headline_ratios():
    mlp = accelerator_cost(mlp_accelerator((17, 420, 420, 14), PURE_PWM(8)))
    k1 = accelerator_cost(kan_accelerator(
        (17, 1, 14), ASPQuantSpec(5, 3, 8, 8, -1.0, 1.0),
        TMDVConfig(8, 4), 128, adc_bits=8))
    area_x = mlp["area_mm2"] / k1["area_mm2"]
    energy_x = mlp["energy_pj"] / k1["energy_pj"]
    latency_x = mlp["latency_ns"] / k1["latency_ns"]
    assert 30 < area_x < 55, area_x        # paper 41.78x
    assert 55 < energy_x < 105, energy_x   # paper 77.97x
    assert 20 < latency_x < 40, latency_x  # paper 23.6-29.6x


def test_cost_monotonicity():
    """More grid -> never cheaper B(X) area at fixed n (demux grows)."""
    areas = [
        bx_path_asp(ASPQuantSpec(g, 3, 8, 8, 0.0, 1.0))["area_um2"]
        for g in (32, 48, 64)
    ]
    assert areas == sorted(areas)
    # conventional scales ~linearly in G+K
    c8 = bx_path_conventional(ASPQuantSpec(8, 3, 8, 8, 0.0, 1.0))["area_um2"]
    c64 = bx_path_conventional(ASPQuantSpec(64, 3, 8, 8, 0.0, 1.0))["area_um2"]
    assert 4 < c64 / c8 < 8  # (64+3)/(8+3) ~ 6.1


def test_search_max_grid_respects_constraints():
    hc = HardwareConstraints(max_area_mm2=0.02, max_energy_pj=300,
                             max_latency_ns=700)
    g, cost = search_max_grid((17, 1, 14), hc)
    assert g is not None
    assert check_constraints(cost, hc)
    # the next G up must violate (maximality) or be infeasible
    try:
        from repro.core.neurosim import _cost_for
        from repro.core.tmdv import TMDVConfig as T
        nxt = _cost_for((17, 1, 14), g + 1, 3, 8, T(8, 4), 128, 8)
        assert not check_constraints(nxt, hc)
    except ValueError:
        pass  # G+1 doesn't satisfy eq. (6)


def test_search_infeasible_returns_none():
    hc = HardwareConstraints(max_area_mm2=1e-9)
    g, cost = search_max_grid((17, 1, 14), hc)
    assert g is None and cost is None
