"""Attention-backend parity suite: the fused Pallas flash-attention kernel
("flash") vs the chunked XLA composition ("ref") across mask kinds, GQA
ratios, odd sequence lengths, and the serving decode paths — plus
regressions for the two chunked-attention bugfixes (non-multiple-of-chunk
sequences abandoning the memory-bounded path; fully-masked query rows
softmaxing into garbage instead of zeros).

The documented ref tolerance: both backends compute logits/softmax in f32
but associate the reductions differently (online softmax vs one-shot), so
outputs agree to ~1e-5 absolute on unit-scale inputs, not bitwise.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.configs.registry import smoke_config
from repro.kernels.attention import flash_attention
from repro.models import layers as L

TOL = dict(rtol=2e-5, atol=2e-5)  # the documented flash-vs-ref tolerance


def _qkv_rand(b, s, hq, hkv, d, t=None, seed=0):
    t = s if t is None else t
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, hkv, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("kind", ["global", "local", "bidir"])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (4, 1)])
def test_flash_matches_ref_kinds_and_gqa(kind, hq, hkv):
    cfg = smoke_config("qwen2.5-14b")
    if kind == "local":
        cfg = dataclasses.replace(cfg, window_size=7)
    q, k, v = _qkv_rand(2, 33, hq, hkv, 16, seed=hash((kind, hq)) % 1000)
    ref = L._sdpa_ref(q, k, v, cfg, kind)
    with runtime.use_attn_backend("flash"):
        out = L._sdpa(q, k, v, cfg, kind)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_flash_matches_ref_softcap_and_cross_lengths():
    """Softcap applies before masking in both backends; cross attention has
    S != T and no positional mask."""
    cfg = dataclasses.replace(smoke_config("gemma2-27b"), window_size=0)
    assert cfg.attn_logit_softcap and cfg.attn_logit_softcap > 0.0
    q, k, v = _qkv_rand(2, 9, 4, 2, 16, t=24, seed=3)
    ref = L._sdpa_ref(q, k, v, cfg, "cross")
    with runtime.use_attn_backend("flash"):
        out = L._sdpa(q, k, v, cfg, "cross")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    # and causal with softcap, S < T (right-aligned default qpos)
    ref = L._sdpa_ref(q, k, v, cfg, "global")
    with runtime.use_attn_backend("flash"):
        out = L._sdpa(q, k, v, cfg, "global")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("s", [63, 65])
def test_chunked_remainder_stays_memory_bounded(s, monkeypatch):
    """Bugfix regression: s % ATTN_CHUNK != 0 must still take the chunked
    scan (padded final chunk), never fall back to one full-(S,T) call, and
    must equal the single-chunk oracle.  Run at chunk=64 so the suite stays
    fast; 63/65 are the small-geometry counterparts of 1023/1025."""
    chunk = 64
    seen = []
    orig_chunk_fn = L._sdpa_chunk

    def spy(qc, qpos, k, v, kpos, cfg, kind):
        seen.append(qc.shape[1])
        return orig_chunk_fn(qc, qpos, k, v, kpos, cfg, kind)

    cfg = smoke_config("qwen2.5-14b")
    q, k, v = _qkv_rand(1, s, 4, 2, 16, seed=s)
    monkeypatch.setattr(L, "ATTN_CHUNK", chunk)
    monkeypatch.setattr(L, "_sdpa_chunk", spy)
    out = L._sdpa_ref(q, k, v, cfg, "global")
    # every chunk the scan processed was memory-bounded
    assert seen and all(c <= chunk for c in seen), seen
    monkeypatch.setattr(L, "ATTN_CHUNK", 10**9)
    direct = L._sdpa_ref(q, k, v, cfg, "global")
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)
    assert bool(jnp.isfinite(out).all())


def test_chunked_remainder_true_shape_1025(monkeypatch):
    """The literal failing shape from the issue: s=1025 with the real
    ATTN_CHUNK=1024 takes the padded scan and matches the direct path."""
    cfg = smoke_config("qwen2.5-14b")
    q, k, v = _qkv_rand(1, 1025, 2, 1, 8, seed=7)
    out = L._sdpa_ref(q, k, v, cfg, "global")
    monkeypatch.setattr(L, "ATTN_CHUNK", 10**9)
    direct = L._sdpa_ref(q, k, v, cfg, "global")
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


def test_masked_softmax_fully_masked_rows_are_zero():
    """Bugfix regression: under the -1e30 mask constant a fully-masked row
    used to softmax into a uniform average of garbage; the guarded
    denominator must produce exact zeros (both backends, no NaNs)."""
    cfg = smoke_config("qwen2.5-14b")
    b, s, hq, hkv, d = 1, 8, 4, 2, 16
    q, k, v = _qkv_rand(b, s, hq, hkv, d, seed=11)
    qpos = jnp.concatenate(
        [jnp.arange(s - 3, dtype=jnp.int32), jnp.full((3,), -1, jnp.int32)]
    )
    ref = L._sdpa_ref(q, k, v, cfg, "global", qpos=qpos)
    with runtime.use_attn_backend("flash"):
        out = L._sdpa(q, k, v, cfg, "global", qpos=qpos)
    for o in (ref, out):
        assert bool(jnp.isfinite(o).all())
        assert float(jnp.abs(o[:, -3:]).max()) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    # decode-path variant: a batch row whose key mask is all-False
    qd = q[:, :1]
    mask = jnp.zeros((b, s), bool)
    od = L._sdpa_batch_masked(qd, k, v, mask, cfg)
    assert bool(jnp.isfinite(od).all()) and float(jnp.abs(od).max()) == 0.0


@pytest.mark.parametrize("kind", ["global", "local"])
def test_decode_parity_including_rolling_window_edge(kind):
    """Step-by-step decode parity, ref vs flash, driving the rolling-window
    cache across the pos == window boundary (slot reuse starts there)."""
    if kind == "local":
        cfg = dataclasses.replace(smoke_config("mixtral-8x7b"), window_size=8)
        steps = 13  # crosses pos == 7 (window-1), 8 (window), 9, ...
    else:
        cfg = smoke_config("qwen2.5-14b")
        steps = 5
    b = 2
    p = L.init_attention(jax.random.PRNGKey(3), cfg)
    cache_r = L.init_kv_cache(cfg, b, 32, kind)
    cache_f = L.init_kv_cache(cfg, b, 32, kind)
    key = jax.random.PRNGKey(0)
    for i in range(steps):
        x = jax.random.normal(
            jax.random.fold_in(key, i), (b, 1, cfg.d_model), jnp.float32
        ) * 0.3
        pos = jnp.full((b,), i, jnp.int32)
        o_r, cache_r = L.attention_decode(p, x, cache_r, pos, cfg, kind)
        with runtime.use_attn_backend("flash"):
            o_f, cache_f = L.attention_decode(p, x, cache_f, pos, cfg, kind)
        np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_r),
                                   err_msg=f"pos={i}", **TOL)


def test_decode_cross_attention_parity():
    cfg = smoke_config("qwen2.5-14b")
    b = 2
    p = L.init_attention(jax.random.PRNGKey(5), cfg, cross=True)
    key = jax.random.PRNGKey(6)
    enc = jax.random.normal(key, (b, 12, cfg.d_model), jnp.float32) * 0.3
    cache = {
        "k": jnp.einsum("bsd,dhk->bshk", enc, p["wk"]),
        "v": jnp.einsum("bsd,dhk->bshk", enc, p["wv"]),
    }
    x = jax.random.normal(key, (b, 1, cfg.d_model), jnp.float32) * 0.3
    pos = jnp.zeros((b,), jnp.int32)
    o_r, _ = L.attention_decode(p, x, cache, pos, cfg, "cross")
    with runtime.use_attn_backend("flash"):
        o_f, _ = L.attention_decode(p, x, cache, pos, cfg, "cross")
    np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_r), **TOL)


def test_attn_backend_resolution_precedence(monkeypatch):
    """explicit arg > use_attn_backend scope > REPRO_ATTN_BACKEND env >
    hardware default; unknown names raise."""
    monkeypatch.delenv(runtime.ENV_ATTN_BACKEND_VAR, raising=False)
    assert runtime.resolve_attn_backend() == runtime.default_attn_backend()
    monkeypatch.setenv(runtime.ENV_ATTN_BACKEND_VAR, "flash")
    assert runtime.resolve_attn_backend() == "flash"
    with runtime.use_attn_backend("ref"):
        assert runtime.resolve_attn_backend() == "ref"          # scope > env
        assert runtime.resolve_attn_backend("flash") == "flash"  # arg > scope
        with runtime.use_attn_backend(None):                     # passthrough
            assert runtime.resolve_attn_backend() == "ref"
    assert runtime.resolve_attn_backend() == "flash"
    with pytest.raises(ValueError):
        runtime.resolve_attn_backend("sdpa-magic")
    with pytest.raises(ValueError):
        with runtime.use_attn_backend("sdpa-magic"):
            pass
    assert set(runtime.available_attn_backends()) >= {"ref", "flash"}


def test_serve_engine_flash_attention_same_tokens():
    """End-to-end serving regression: the continuous-batching engine decodes
    the SAME greedy tokens with flash attention as with the XLA ref (the
    flash-vs-ref numerical gap is far below the argmax margin), and the
    backend is baked into the compiled steps (attn_backend in stats)."""
    from repro.serve.engine import Request, ServeEngine
    from repro.models.model import init_params

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    params = init_params(jax.random.PRNGKey(0), cfg)

    def make_reqs():
        rng = jax.random.PRNGKey(42)
        reqs = []
        for rid in range(3):
            rng, k = jax.random.split(rng)
            prompt = jax.random.randint(k, (6,), 3, cfg.vocab_size).tolist()
            reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=4))
        return reqs

    outs = {}
    for backend in ("ref", "flash"):
        eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                          attn_backend=backend)
        assert eng.compile_stats()["attn_backend"] == backend
        outs[backend] = {r.rid: r.output for r in eng.run(make_reqs())}
    assert outs["ref"] == outs["flash"]


def test_serve_engine_rejects_unknown_attn_backend():
    from repro.serve.engine import ServeEngine
    from repro.models.model import init_params

    cfg = smoke_config("qwen2.5-14b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        ServeEngine(params, cfg, slots=2, max_len=32,
                    attn_backend="sdpa-magic")


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)
def test_flash_attention_composes_with_mesh_sharding():
    """Flash attention under the PR-4 sharded engine (slots/KV on "data",
    KAN-FFN channels on "model") serves the same tokens as the unsharded
    flash engine — attention composes with mesh sharding."""
    from repro.launch.mesh import make_local_mesh
    from repro.serve.engine import Request, ServeEngine
    from repro.models.model import init_params

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    params = init_params(jax.random.PRNGKey(0), cfg)

    def make_reqs():
        rng = jax.random.PRNGKey(21)
        reqs = []
        for rid in range(3):
            rng, k = jax.random.split(rng)
            prompt = jax.random.randint(k, (6,), 3, cfg.vocab_size).tolist()
            reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=3))
        return reqs

    runtime.reset_cache()
    e0 = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                     attn_backend="flash")
    out0 = {r.rid: r.output for r in e0.run(make_reqs())}

    n = len(jax.devices())
    mesh = make_local_mesh(2, 2) if n >= 4 else make_local_mesh(2, 1)
    e1 = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                     attn_backend="flash", mesh=mesh)
    out1 = {r.rid: r.output for r in e1.run(make_reqs())}
    assert out0 == out1


def test_flash_attention_kernel_rejects_bad_args():
    q, k, v = _qkv_rand(1, 8, 4, 2, 16, seed=0)
    with pytest.raises(ValueError):
        flash_attention(q, k, v, kind="sideways")
    q3 = jnp.zeros((1, 8, 3, 16))  # Hq=3 not a multiple of Hkv=2
    with pytest.raises(ValueError):
        flash_attention(q3, k, v, kind="causal")
