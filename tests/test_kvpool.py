"""Paged KV pool correctness (the PR-7 acceptance contract).

Two layers of guarantees:

  * :class:`KVBlockPool` bookkeeping — free-list allocation, refcounts,
    hash-keyed prefix publish/match, LRU eviction and the
    ``check_consistent`` partition invariant (every allocatable block is
    in exactly one of free / referenced / evictable, hash maps mirror).
  * The load-bearing serving invariant: greedy token streams on the PAGED
    engine are BIT-IDENTICAL to the contiguous-slab engine on the same
    request set — per runtime backend (``ref`` / ``pallas`` / quiet
    ``acim``), with chunked prefill, with prefix-cache hits splicing
    shared blocks, and on a 1x1 mesh — because the paged decode gathers
    the block table into exactly the contiguous cache's view and masked
    softmax lanes contribute exact zeros regardless of stale block
    contents.
"""

import random

import jax
import pytest

from conftest import ensure_quiet_acim_backend
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvpool import (
    SCRATCH_BLOCK,
    KVBlockPool,
    KVPoolExhausted,
    hash_token_blocks,
)

# zero-noise acim (conftest harness): traces the same program as "pallas",
# so its greedy streams take part in the bit-identity acceptance; the
# shared session-scoped ``kan_setup`` fixture also lives in conftest
ensure_quiet_acim_backend()


def make_reqs(cfg, n=2, plen=5, max_new=3, seed=42, prefix=()):
    rng = jax.random.PRNGKey(seed)
    reqs = []
    for rid in range(n):
        rng, k = jax.random.split(rng)
        tail = jax.random.randint(k, (plen,), 3, cfg.vocab_size).tolist()
        reqs.append(Request(rid=rid, prompt=list(prefix) + tail,
                            max_new_tokens=max_new))
    return reqs


def streams(engine, reqs):
    return {r.rid: r.output for r in engine.run(reqs)}


# ---------------------------------------------------------------------------
# KVBlockPool bookkeeping
# ---------------------------------------------------------------------------


def test_pool_alloc_release_roundtrip():
    pool = KVBlockPool(num_blocks=5, block_size=8)
    got = [pool.alloc() for _ in range(4)]  # all allocatable blocks
    assert sorted(got) == [1, 2, 3, 4]      # scratch block 0 never handed out
    assert pool.blocks_in_use() == 4
    assert pool.peak_in_use == 4
    with pytest.raises(KVPoolExhausted):
        pool.alloc()
    for bid in got:
        pool.release(bid)
    assert pool.blocks_in_use() == 0
    assert pool.peak_in_use == 4            # peak survives the drain
    pool.check_consistent()
    # released ids are allocatable again
    assert sorted(pool.alloc() for _ in range(4)) == [1, 2, 3, 4]


def test_pool_refcount_and_scratch_guards():
    pool = KVBlockPool(num_blocks=4, block_size=2)
    bid = pool.alloc()
    pool.retain(bid)
    pool.release(bid)
    assert pool.blocks_in_use() == 1        # still referenced once
    pool.release(bid)
    assert pool.blocks_in_use() == 0
    with pytest.raises(ValueError):
        pool.release(bid)                   # double release
    with pytest.raises(ValueError):
        pool.retain(SCRATCH_BLOCK)
    with pytest.raises(ValueError):
        KVBlockPool(num_blocks=1, block_size=2)
    with pytest.raises(ValueError):
        KVBlockPool(num_blocks=4, block_size=0)
    pool.check_consistent()


def test_hash_token_blocks_chain_property():
    # only FULL blocks are hashed, and hash i folds in hash i-1: equal
    # hashes at chunk i imply the whole prefix matches
    assert hash_token_blocks([1, 2, 3], 4) == []
    a = hash_token_blocks([1, 2, 3, 4, 5, 6, 7, 8, 9], 4)
    b = hash_token_blocks([1, 2, 3, 4, 5, 6, 7, 99], 4)
    assert len(a) == 2 and len(b) == 2
    assert a[0] == b[0] and a[1] != b[1]
    c = hash_token_blocks([9, 2, 3, 4, 5, 6, 7, 8], 4)
    assert c[0] != a[0]
    assert c[1] != a[1]                     # divergence propagates


def test_pool_prefix_publish_match_evict():
    pool = KVBlockPool(num_blocks=6, block_size=4)
    prompt = [1, 2, 3, 4, 5, 6, 7, 8, 9]    # 2 full blocks + partial
    blocks = [pool.alloc(), pool.alloc()]
    pool.publish_prefix(prompt, blocks)
    for bid in blocks:
        pool.release(bid)
    assert pool.blocks_cached() == 2        # kept evictable for future hits
    # same prefix, different tail: both full blocks hit and are retained
    hit = pool.match_prefix([1, 2, 3, 4, 5, 6, 7, 8, 42], max_tokens=8)
    assert hit == blocks
    assert pool.hits == 2 and pool.misses == 0
    assert pool.blocks_in_use() == 2 and pool.blocks_cached() == 0
    # max_tokens caps the usable prefix to FULL blocks below it
    pool2 = KVBlockPool(num_blocks=6, block_size=4)
    b2 = [pool2.alloc(), pool2.alloc()]
    pool2.publish_prefix(prompt, b2)
    assert pool2.match_prefix(prompt, max_tokens=len(prompt) - 1) == b2[:2]
    assert pool2.match_prefix([1, 2, 3, 4, 5], max_tokens=4) == b2[:1]
    # divergent first block: clean miss
    assert pool.match_prefix([9, 9, 9, 9], max_tokens=4) == []
    for bid in hit:
        pool.release(bid)
    pool.check_consistent()
    # exhaustion now evicts the LRU cached block instead of raising
    keep = [pool.alloc() for _ in range(3)]
    assert pool.evictions == 0
    extra = pool.alloc()                    # 4th + 5th: evict cached blocks
    extra2 = pool.alloc()
    assert pool.evictions == 2
    assert pool.blocks_cached() == 0
    assert sorted(keep + [extra, extra2]) == [1, 2, 3, 4, 5]
    pool.check_consistent()


def test_pool_prefix_cache_off_degrades_to_allocator():
    pool = KVBlockPool(num_blocks=4, block_size=2, prefix_cache=False)
    bid = pool.alloc()
    pool.publish_prefix([1, 2, 3, 4], [bid])
    pool.release(bid)
    assert pool.blocks_cached() == 0        # nothing published
    assert pool.match_prefix([1, 2, 3, 4]) == []
    assert pool.hit_rate() == 0.0
    pool.check_consistent()


def test_pool_randomized_workout_stays_consistent():
    rng = random.Random(7)
    pool = KVBlockPool(num_blocks=12, block_size=2)
    held = []
    for step in range(500):
        op = rng.random()
        if op < 0.45:
            try:
                held.append(pool.alloc())
            except KVPoolExhausted:
                pass
        elif op < 0.8 and held:
            pool.release(held.pop(rng.randrange(len(held))))
        else:
            prompt = [rng.randrange(50) for _ in range(rng.randrange(1, 9))]
            hit = pool.match_prefix(prompt)
            if not hit and len(prompt) >= 2 and held:
                pool.publish_prefix(prompt, held[:len(prompt) // 2])
            held.extend(hit)
        pool.check_consistent()
    stats = pool.stats()
    assert stats["allocs"] > 0
    assert stats["blocks_in_use"] == len(set(held))  # held may alias hits
    assert stats["blocks_in_use_peak"] <= pool.num_blocks - 1


# ---------------------------------------------------------------------------
# Engine: paged == contiguous bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas", "acim-quiet"])
def test_paged_streams_bit_identical_to_contiguous(kan_setup, backend):
    """Acceptance: whole-prompt paged prefill + paged decode serve the
    exact greedy streams of the contiguous slab, per runtime backend."""
    cfg, params = kan_setup
    base = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                       kan_backend=backend)
    want = streams(base, make_reqs(cfg, n=4, plen=5, max_new=4))
    paged = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                        kan_backend=backend, kv_block_size=8)
    got = streams(paged, make_reqs(cfg, n=4, plen=5, max_new=4))
    assert got == want
    paged.pool.check_consistent()
    # every slot drained back to the free list, no block leaked
    assert paged._free_slots == list(range(paged.slots))
    assert paged.pool.blocks_in_use() == 0


def test_chunked_prefill_streams_bit_identical(kan_setup):
    """Chunked prefill (interleaved with pooled decode by the scheduler)
    must not change a single token vs the contiguous path."""
    cfg, params = kan_setup
    base = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True)
    want = streams(base, make_reqs(cfg, n=3, plen=11, max_new=4))
    paged = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                        kv_block_size=8, prefill_chunk=4)
    got = streams(paged, make_reqs(cfg, n=3, plen=11, max_new=4))
    assert got == want
    # 11-token prompts in 4-token chunks, bucketed: one prefill trace
    assert paged.compile_stats()["prefill_traces"] == 1
    paged.pool.check_consistent()
    assert paged.pool.blocks_in_use() == 0


def test_prefix_cache_hits_and_streams_match(kan_setup):
    """Shared-prefix requests splice cached blocks (hit rate > 0) and STILL
    serve bit-identical streams — a cache hit must be invisible."""
    cfg, params = kan_setup
    prefix = [7] * 16                        # 2 full 8-token blocks
    base = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True)
    want = streams(base, make_reqs(cfg, n=4, plen=3, max_new=3,
                                   prefix=prefix))
    paged = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                        kv_block_size=8, prefix_cache=True)
    got = streams(paged, make_reqs(cfg, n=4, plen=3, max_new=3,
                                   prefix=prefix))
    assert got == want
    s = paged.kv_stats()
    assert s["prefix_hits"] > 0
    assert s["prefix_hit_rate"] > 0
    assert s["blocks_cached"] > 0            # the shared blocks stay cached
    paged.pool.check_consistent()
    # cache off: same streams, no hits
    off = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                      kv_block_size=8, prefix_cache=False)
    assert streams(off, make_reqs(cfg, n=4, plen=3, max_new=3,
                                  prefix=prefix)) == want
    assert off.kv_stats()["prefix_hits"] == 0


def test_paged_mesh_1x1_matches_contiguous(kan_setup):
    """Paged serving under a mesh (1x1 degenerate case — sharding machinery
    on, one device) matches the unmeshed contiguous engine."""
    from repro.launch.mesh import make_local_mesh

    cfg, params = kan_setup
    base = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True)
    want = streams(base, make_reqs(cfg, n=3, plen=5, max_new=3))
    paged = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                        kv_block_size=8, prefill_chunk=4,
                        mesh=make_local_mesh(1, 1))
    assert streams(paged, make_reqs(cfg, n=3, plen=5, max_new=3)) == want


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)
def test_paged_data_mesh_matches_contiguous(kan_setup):
    """Paged KV blocks shard across the data axis; streams must not move."""
    from repro.launch.mesh import make_local_mesh

    cfg, params = kan_setup
    n = len(jax.devices())
    base = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True)
    want = streams(base, make_reqs(cfg, n=3, plen=5, max_new=3))
    paged = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                        kv_block_size=8, mesh=make_local_mesh(n, 1))
    assert streams(paged, make_reqs(cfg, n=3, plen=5, max_new=3)) == want
    paged.pool.check_consistent()


# ---------------------------------------------------------------------------
# Engine: slot free-list + pool lifecycle
# ---------------------------------------------------------------------------


def test_free_slot_list_tracks_slot_lifecycle(kan_setup):
    """The O(log slots) free-slot list is the slot-occupancy ground truth:
    it mirrors ``active`` through take/release and survives mid-prefill
    aborts (pool exhaustion releases the claimed slot)."""
    cfg, params = kan_setup
    eng = ServeEngine(params, cfg, slots=3, max_len=32, kan_deploy=True,
                      kv_block_size=8)

    def check():
        free = set(eng._free_slots)
        busy = {i for i, r in enumerate(eng.active)
                if r is not None} | set(eng._prefilling)
        assert eng._free_slots == sorted(free)   # kept sorted (bisect)
        assert free | busy == set(range(eng.slots))
        assert not free & busy

    check()
    reqs = make_reqs(cfg, n=3, plen=5, max_new=2)
    logits = eng._prefill_slot(eng._free_slot(), reqs[0])
    assert logits is not None
    check()
    assert eng._free_slot() == 1                 # lowest free slot first
    with pytest.raises(RuntimeError):
        eng._take_slot(0)                        # slot 0 is occupied
    eng.release_slot(0)
    check()
    assert eng._free_slot() == 0
    # release is idempotent-hostile by design: double release must raise
    # via _take_slot when re-claiming an already-free slot is attempted
    eng._take_slot(0)
    eng.release_slot(0)
    check()
    assert eng.pool.blocks_in_use() == 0


def test_paged_engine_validation(kan_setup):
    cfg, params = kan_setup
    with pytest.raises(ValueError):  # not a multiple of the flash KV tile
        ServeEngine(params, cfg, slots=2, max_len=32, kv_block_size=6)
    with pytest.raises(ValueError):  # must divide max_len
        ServeEngine(params, cfg, slots=2, max_len=40, kv_block_size=16)
    with pytest.raises(ValueError):  # chunked prefill needs the pool
        ServeEngine(params, cfg, slots=2, max_len=32, prefill_chunk=4)


def test_pool_exhaustion_surfaces_and_releases_slot(kan_setup):
    """An undersized pool fails loudly at admission (KVPoolExhausted names
    the fix) and the claimed slot goes back to the free list."""
    cfg, params = kan_setup
    eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                      kv_block_size=8, kv_blocks=2)  # 1 allocatable block
    req = make_reqs(cfg, n=1, plen=10, max_new=2)[0]  # needs 2 blocks
    with pytest.raises(KVPoolExhausted):
        eng._prefill_slot(eng._free_slot(), req)
    assert eng._free_slots == [0, 1]
    assert eng.pool.blocks_in_use() == 0
    eng.pool.check_consistent()
