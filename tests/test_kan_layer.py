"""KAN layer: float/quantized agreement, grid extension, param accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.asp_quant import ASPQuantSpec
from repro.core.kan_layer import (
    KANSpec,
    extend_layer_grid,
    init_kan_layer,
    init_kan_network,
    kan_layer_apply,
    kan_layer_apply_quantized,
    kan_network_apply,
    param_count,
    quantize_kan_layer,
)


def test_param_count_matches_paper():
    assert param_count(KANSpec(dims=(17, 1, 14), grid_size=5)) == 279    # KAN1
    assert param_count(KANSpec(dims=(17, 1, 14), grid_size=68)) == 2232  # KAN2


@pytest.mark.parametrize("g", [5, 16])
def test_quantized_path_close_to_float(g):
    kspec = KANSpec(dims=(17, 1, 14), grid_size=g)
    spec = kspec.layer_spec()
    key = jax.random.PRNGKey(0)
    params = init_kan_network(key, kspec)
    x = jax.random.uniform(key, (64, 17), minval=-1, maxval=1)
    y = kan_network_apply(params, x, kspec)
    qp = [quantize_kan_layer(p, spec) for p in params]
    yq = kan_network_apply(None, x, kspec, quantized=True, qparams_list=qp)
    assert jnp.isfinite(y).all() and jnp.isfinite(yq).all()
    # 8-bit path: bounded absolute error relative to the output scale
    err = float(jnp.abs(y - yq).max())
    scale = float(jnp.abs(y).max())
    assert err < 0.05 * scale + 0.02, (err, scale)


def test_grid_extension_preserves_function():
    spec = ASPQuantSpec(grid_size=5, order=3, n_bits=8, lo=-1.0, hi=1.0)
    key = jax.random.PRNGKey(1)
    p = init_kan_layer(key, 9, 4, spec)
    p2 = extend_layer_grid(p, spec, 20)
    spec20 = dataclasses.replace(spec, grid_size=20)
    x = jnp.linspace(-1, 1, 161)[:, None] * jnp.ones((1, 9))
    y1 = kan_layer_apply(p, x, spec)
    y2 = kan_layer_apply(p2, x, spec20)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    assert p2["c"].shape == (9, 23, 4)


@pytest.mark.parametrize("g_old,g_new", [(5, 20), (5, 68), (8, 32), (16, 64)])
def test_grid_extension_round_trip_dense(g_old, g_new):
    """Round-trip: the extended-grid spline reproduces the old spline to
    <1e-4 on a dense sample of the whole domain (original-KAN §2.5 transfer;
    the KAN1 -> KAN2 G=5 -> 68 case is the paper's own refinement step)."""
    spec = ASPQuantSpec(grid_size=g_old, order=3, n_bits=8, lo=-1.0, hi=1.0)
    key = jax.random.PRNGKey(7)
    p = init_kan_layer(key, 6, 5, spec)
    p2 = extend_layer_grid(p, spec, g_new)
    spec_new = dataclasses.replace(spec, grid_size=g_new)
    assert p2["c"].shape == (6, g_new + spec.order, 5)
    np.testing.assert_array_equal(np.asarray(p2["w_b"]),
                                  np.asarray(p["w_b"]))  # w_b untouched
    x = jnp.linspace(-1.0, 1.0, 1025)[:, None] * jnp.ones((1, 6))
    y_old = kan_layer_apply(p, x, spec)
    y_new = kan_layer_apply(p2, x, spec_new)
    err = float(jnp.abs(y_old - y_new).max())
    assert err < 1e-4, err


def test_grid_extension_composes_with_quantized_path():
    """Extended layer still quantizes/deploys: G=68 fits 8 bits (LD=1)."""
    spec = ASPQuantSpec(grid_size=5, order=3, n_bits=8, lo=-1.0, hi=1.0)
    key = jax.random.PRNGKey(8)
    p = init_kan_layer(key, 4, 3, spec)
    p2 = extend_layer_grid(p, spec, 68)
    spec68 = dataclasses.replace(spec, grid_size=68)
    qp = quantize_kan_layer(p2, spec68)
    x = jax.random.uniform(key, (32, 4), minval=-1, maxval=1)
    y = kan_layer_apply(p2, x, spec68)
    yq = kan_layer_apply_quantized(qp, x, spec68)
    err = float(jnp.abs(y - yq).max())
    scale = float(jnp.abs(y).max())
    assert err < 0.05 * scale + 0.02, (err, scale)


def test_param_count_formula_general():
    """#Param = edges * (G + K + 1), the paper's counting convention."""
    assert param_count(KANSpec(dims=(4, 7), grid_size=6, order=2)) \
        == 4 * 7 * (6 + 2 + 1)
    assert param_count(KANSpec(dims=(3, 5, 2, 8), grid_size=10, order=3)) \
        == (3 * 5 + 5 * 2 + 2 * 8) * 14
    # paper table: KAN2 = KAN1 grid-extended, same edge count
    kan1 = KANSpec(dims=(17, 1, 14), grid_size=5)
    kan2 = KANSpec(dims=(17, 1, 14), grid_size=68)
    assert param_count(kan2) / param_count(kan1) == 72 / 9


def test_gradients_flow():
    kspec = KANSpec(dims=(5, 3, 2), grid_size=4)
    key = jax.random.PRNGKey(2)
    params = init_kan_network(key, kspec)
    x = jax.random.uniform(key, (8, 5), minval=-1, maxval=1)

    def loss(params):
        return jnp.sum(kan_network_apply(params, x, kspec) ** 2)

    grads = jax.grad(loss)(params)
    norms = [float(jnp.abs(g).max()) for p in grads for g in p.values()]
    assert all(np.isfinite(norms)) and max(norms) > 0


def test_relu_residual_branch_matches_paper_eq1():
    """phi(x) = w_b * relu(x) + spline(x): zero spline coeffs -> pure ReLU."""
    spec = ASPQuantSpec(grid_size=5, order=3, n_bits=8, lo=-1.0, hi=1.0)
    key = jax.random.PRNGKey(3)
    p = init_kan_layer(key, 4, 3, spec)
    p = {"c": jnp.zeros_like(p["c"]), "w_b": p["w_b"]}
    x = jax.random.uniform(key, (16, 4), minval=-1, maxval=1)
    y = kan_layer_apply(p, x, spec)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jax.nn.relu(x) @ p["w_b"]), atol=1e-6
    )
