"""Fused multi-layer Pallas pipeline vs the layered quantized reference.

The contract (kernels/kan_spline/pipeline.py): running the whole stack in
the fused executor — int codes across layer boundaries, requantization fused
into the producing kernel — must reproduce the layered
``kan_layer_apply_quantized`` + tanh-rescale composition:

  * the int32 codes each layer hands to the next are BIT-IDENTICAL to the
    reference's re-quantization (the quantizer output is discrete, so the
    fused boundary must land on exactly the same codes);
  * the final f32 output agrees to float-ulp tolerance (the banded matmul
    is tiled/padded differently, so bit-identity of the f32 accumulation is
    not required — only of the code stream).

Shapes deliberately include ragged B/F/O (nothing a multiple of the block
sizes), multi-layer stacks, and both paper configs: KAN1 (17,1,14) G=5 and
KAN2 G=68.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.asp_quant import quantize_input
from repro.core.kan_layer import (
    KANSpec,
    init_kan_network,
    kan_layer_apply_quantized,
    kan_network_apply,
)
from repro.core.kan_network_deploy import (
    deploy_kan_ffn_stack,
    deploy_kan_network,
    kan_network_apply_ref,
    kan_network_deploy_apply,
    quantize_kan_network,
)
from repro.kernels.kan_spline.pipeline import make_pipeline_plan

# (dims, grid, batch) — ragged on purpose; first two are the paper's KAN1/KAN2
SHAPES = [
    ((17, 1, 14), 5, 33),     # KAN1, odd batch
    ((17, 1, 14), 68, 7),     # KAN2 (G=68), tiny batch
    ((3, 2), 4, 1),           # single layer, degenerate everything
    ((5, 9, 3, 2), 8, 130),   # 3-layer stack, batch > one tile
    ((40, 77, 13), 16, 19),   # wide ragged middle
]


def _ref_with_boundary_codes(qparams, x, kspec):
    """Layered reference, also returning each boundary's re-quantized codes."""
    spec = kspec.layer_spec()
    h = x
    codes = []
    n = len(qparams)
    for li in range(n):
        h = kan_layer_apply_quantized(qparams[li], h, spec)
        if li < n - 1:
            h = jnp.tanh(h) * (0.5 * (spec.hi - spec.lo)) \
                + 0.5 * (spec.hi + spec.lo)
            codes.append(quantize_input(h, spec))
    return h, codes


@pytest.mark.parametrize("dims,grid,batch", SHAPES)
def test_fused_pipeline_matches_layered_reference(dims, grid, batch):
    kspec = KANSpec(dims=dims, grid_size=grid)
    key = jax.random.PRNGKey(0)
    params = init_kan_network(key, kspec)
    qparams = quantize_kan_network(params, kspec)
    x = jax.random.uniform(key, (batch, dims[0]), minval=-1.0, maxval=1.0)

    ref, ref_codes = _ref_with_boundary_codes(qparams, x, kspec)
    dep = deploy_kan_network(qparams, kspec, batch=batch)
    out, codes = kan_network_deploy_apply(
        dep, x, interpret=True, return_intermediates=True
    )

    assert out.shape == (batch, dims[-1])
    assert len(codes) == len(ref_codes)
    for li, (c, rc) in enumerate(zip(codes, ref_codes)):
        np.testing.assert_array_equal(
            np.asarray(c), np.asarray(rc),
            err_msg=f"boundary codes after layer {li} not bit-exact",
        )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5
    )


def test_backend_switch_in_kan_network_apply():
    kspec = KANSpec(dims=(17, 1, 14), grid_size=5)
    key = jax.random.PRNGKey(1)
    params = init_kan_network(key, kspec)
    qparams = quantize_kan_network(params, kspec)
    x = jax.random.uniform(key, (12, 17), minval=-1.0, maxval=1.0)

    y_ref = kan_network_apply(None, x, kspec, quantized=True,
                              qparams_list=qparams, backend="ref")
    y_pal = kan_network_apply(None, x, kspec, quantized=True,
                              qparams_list=qparams, backend="pallas",
                              interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_pal), np.asarray(y_ref), atol=1e-5, rtol=1e-5
    )
    with pytest.raises(ValueError):
        kan_network_apply(None, x, kspec, quantized=True,
                          qparams_list=qparams, backend="tpu-magic")


def test_kan_network_apply_ref_equals_layered_composition():
    kspec = KANSpec(dims=(5, 9, 3, 2), grid_size=8)
    key = jax.random.PRNGKey(2)
    qparams = quantize_kan_network(init_kan_network(key, kspec), kspec)
    x = jax.random.uniform(key, (9, 5), minval=-1.0, maxval=1.0)
    a = kan_network_apply_ref(qparams, x, kspec)
    # the eager oracle is BIT-identical to the layered per-layer composition
    spec = kspec.layer_spec()
    h = x
    for li, qp in enumerate(qparams):
        h = kan_layer_apply_quantized(qp, h, spec)
        if li < len(qparams) - 1:
            h = jnp.tanh(h) * (0.5 * (spec.hi - spec.lo)) \
                + 0.5 * (spec.hi + spec.lo)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(h))
    # the runtime-routed "ref" backend (jitted + batch-bucketed) agrees to
    # float-ulp tolerance — XLA may fuse the argument-weights graph with a
    # one-ulp different accumulation than the eager constant-folded oracle
    b = kan_network_apply(None, x, kspec, quantized=True, qparams_list=qparams)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-6, rtol=1e-6)


def test_ffn_stack_raw_residual_matches_composition():
    """residual_raw contract: ReLU branch reads the RAW pre-squash input
    (models/layers._kan_linear), boundary stays tanh->requantize."""
    from repro.core.asp_quant import ASPQuantSpec, dense_basis_from_codes

    spec = ASPQuantSpec(grid_size=8, order=3, n_bits=8, lo=-1.0, hi=1.0)
    dims = (20, 33, 20)
    key = jax.random.PRNGKey(3)
    kspec = KANSpec(dims=dims, grid_size=8)
    qparams = quantize_kan_network(init_kan_network(key, kspec), kspec)
    x = jax.random.normal(key, (13, dims[0])) * 0.7

    # layered reference with the FFN residual convention
    h = x.astype(jnp.float32)
    for qp in qparams:
        codes = quantize_input(jnp.tanh(h), spec)
        basis = dense_basis_from_codes(codes, qp["lut"], spec)
        wc = qp["c_q"].astype(jnp.float32) * qp["c_scale"]
        wb = qp["w_b_q"].astype(jnp.float32) * qp["w_b_scale"]
        f, nb, o = wc.shape
        y = basis.reshape(h.shape[0], f * nb) @ wc.reshape(f * nb, o)
        h = y + jax.nn.relu(h) @ wb
    dep = deploy_kan_ffn_stack(qparams, dims, spec, batch=13)
    out = kan_network_deploy_apply(dep, x, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(h), atol=1e-5, rtol=1e-5
    )


def test_pipeline_plan_geometry_is_consistent():
    """Boundary pads line up (producer op == consumer fp) and blocks divide."""
    kspec = KANSpec(dims=(17, 130, 1, 14), grid_size=68)
    specs = tuple(kspec.layer_spec() for _ in range(3))
    plan = make_pipeline_plan(33, kspec.dims, specs)
    assert plan.bp % plan.layers[0].bb == 0
    for lp in plan.layers:
        assert lp.fp % lp.bf == 0 and lp.op % lp.bo == 0
        assert lp.fp >= lp.f and lp.op >= lp.o
        # basis tile stays inside the VMEM working-set ceiling
        assert lp.bb * lp.bf * lp.spec.num_basis * 4 <= 4 * 1024 * 1024
    for a, b in zip(plan.layers[:-1], plan.layers[1:]):
        assert a.op == b.fp, "codes must flow between layers without reslicing"
        assert a.o == b.f


def test_replan_changes_batch_only():
    kspec = KANSpec(dims=(17, 1, 14), grid_size=5)
    qparams = quantize_kan_network(
        init_kan_network(jax.random.PRNGKey(0), kspec), kspec
    )
    dep = deploy_kan_network(qparams, kspec, batch=8)
    dep2 = dep.replan(640)
    assert dep2.plan.b == 640 and dep2.plan.bp % dep2.plan.layers[0].bb == 0
    assert dep2.layers is dep.layers  # weights/padding are batch-agnostic
    x = jax.random.uniform(jax.random.PRNGKey(1), (640, 17), minval=-1, maxval=1)
    out = kan_network_deploy_apply(dep2, x, interpret=True)
    ref = kan_network_apply_ref(qparams, x, kspec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
