"""repro.tune: co-design search, tile autotuner, tuning artifacts.

Covers the subsystem's contract:

  * Pareto-dominance invariants on the returned front (non-empty, mutually
    non-dominated, covering every feasible evaluated point) and the
    acceptance criterion that at least one searched point dominates the
    un-searched default config on (energy, accuracy);
  * search determinism under a fixed seed;
  * constraint-violating candidates are recorded but never enter the front;
  * tile-tuner validity (every retained candidate plan respects the
    padding/divisibility invariants and is bit-exact vs the heuristic plan,
    outputs AND boundary codes) and transparent plan-cache pickup (no
    consumer retrace after the tuner's warm);
  * artifact round trip: dump -> load -> identical resolved plan and
    candidate, plus schema validation.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime, tune
from repro.core.kan_network_deploy import kan_network_deploy_apply
from repro.core.neurosim import HardwareConstraints
from repro.kernels.kan_spline.pipeline import (
    kan_pipeline,
    make_pipeline_plan,
    validate_plan,
)


@pytest.fixture(autouse=True)
def _fresh_cache():
    runtime.reset_cache()
    yield
    runtime.reset_cache()


@pytest.fixture(scope="module")
def task():
    """One trained base network shared by every search in this module."""
    return tune.make_knot_task(n_train=4096, n_val=512, epochs=60, seed=0,
                               calib_n=128)


SPACE = tune.DesignSpace(grid_size=(3, 5, 8), voltage_bits=(3, 4, 5),
                         array_rows=(128,))


@pytest.fixture(scope="module")
def search_result(task):
    return tune.pareto_search(
        task, SPACE,
        config=tune.SearchConfig(budget=12, n_init=5, seed=0, acim_seeds=2),
    )


# ----------------------------------------------------------------------------
# Pareto search
# ----------------------------------------------------------------------------


def test_front_is_mutually_non_dominated(search_result):
    res = search_result
    assert len(res.front) > 0
    assert res.n_evals == 12
    for p in res.front:
        assert p.feasible
        for q in res.front:
            assert not tune.dominates(q.metrics, p.metrics, res.objectives)


def test_front_covers_every_feasible_point(search_result):
    res = search_result
    front = set(id(p) for p in res.front)
    for p in res.evaluated:
        if not p.feasible or id(p) in front:
            continue
        assert any(
            tune.dominates(q.metrics, p.metrics, res.objectives)
            for q in res.front
        ), f"{p.candidate} is neither on the front nor dominated"


def test_some_point_dominates_the_unsearched_default(search_result):
    """Acceptance: the search beats the shipped defaults on (energy, acc)."""
    res = search_result
    assert res.baseline is not None
    assert res.baseline.candidate == tune.default_candidate()
    dom = res.dominating_baseline(on=("energy_pj", "accuracy"))
    assert len(dom) > 0, [p.to_dict() for p in res.front]


def test_search_is_deterministic_under_a_fixed_seed(task):
    cfg = tune.SearchConfig(budget=5, n_init=3, seed=7, acim_seeds=1)
    r1 = tune.pareto_search(task, SPACE, config=cfg)
    r2 = tune.pareto_search(task, SPACE, config=cfg)
    assert r1.to_dict() == r2.to_dict()
    assert [p.candidate for p in r1.evaluated] == \
        [p.candidate for p in r2.evaluated]


def test_constraint_violators_never_enter_the_front():
    # cost-only mode (task=None): fast, and constraints bind on energy
    space = tune.DesignSpace(grid_size=(3, 5, 8, 16, 32),
                             voltage_bits=(3, 4, 5), array_rows=(128,),
                             use_sam=(False,))
    hc = HardwareConstraints(max_energy_pj=260.0)
    res = tune.pareto_search(
        None, space, constraints=hc,
        config=tune.SearchConfig(budget=20, n_init=10, seed=1),
    )
    infeasible = [p for p in res.evaluated if not p.feasible]
    assert infeasible, "constraint was never exercised"
    for p in infeasible:
        assert p.metrics["energy_pj"] > hc.max_energy_pj
        assert p not in res.front
    for p in res.front:
        assert p.metrics["energy_pj"] <= hc.max_energy_pj


def test_sampling_rejects_powergap_invalid_bit_allocations():
    """Seeded sampling REJECTS (never clamps) mixed allocations that are
    PowerGap-invalid for the sampled grid: with G=32 on one axis value and
    4-bit layers on another, no (32, ..4..) combination may ever be
    proposed — by ``sample`` or by ``neighbors`` — while the valid
    combinations still appear (the sampler must not starve)."""
    from repro.core.asp_quant import max_ld

    space = tune.DesignSpace(
        grid_size=(5, 32), n_bits=(8, 16),
        layer_bits=((), (8, 4), (4, 4)),
        voltage_bits=(3, 4), array_rows=(128,), use_sam=(False,),
    )
    rng = np.random.default_rng(0)
    cands = space.sample(rng, 200)
    assert len(cands) == 200
    seen = set()
    for cand in cands:
        assert space.is_valid(cand)
        for b in (cand.n_bits,) + cand.layer_bits:
            assert max_ld(cand.grid_size, b) >= 0, cand
        seen.add((cand.grid_size, cand.layer_bits))
        for nb in space.neighbors(cand, rng, n=2):
            assert space.is_valid(nb), (cand, nb)
    # the valid mixed cells are reachable, the invalid ones never are
    assert (5, (4, 4)) in seen and (5, (8, 4)) in seen
    assert not any(g == 32 and 4 in lb for g, lb in seen)


def test_invalid_bit_allocations_never_reach_the_front():
    """End-to-end regression: a seeded cost-only search over a space whose
    axes CAN combine into PowerGap-invalid candidates evaluates only valid
    ones — nothing invalid is scored, let alone fronted."""
    from repro.core.asp_quant import max_ld

    space = tune.DesignSpace(
        grid_size=(5, 8, 32), n_bits=(8,),
        layer_bits=((), (8, 4), (4, 4)),
        voltage_bits=(3, 4), array_rows=(128,), use_sam=(False,),
    )
    res = tune.pareto_search(
        None, space, config=tune.SearchConfig(budget=24, n_init=12, seed=3),
    )
    assert res.evaluated
    for p in tuple(res.evaluated) + tuple(res.front):
        cand = p.candidate
        assert space.is_valid(cand), cand
        for b in (cand.n_bits,) + cand.layer_bits:
            assert max_ld(cand.grid_size, b) >= 0, cand


def test_kan_cost_raises_on_invalid_layer_bits_never_clamps():
    from repro.core.neurosim import kan_cost

    cand = tune.Candidate(grid_size=32, layer_bits=(4, 8))
    with pytest.raises(ValueError, match="PowerGap-invalid"):
        kan_cost((17, 1, 14), 32, 3, 8, cand.input_gen(), 128, 8,
                 layer_bits=cand.layer_bits)


def test_cost_only_metrics_match_the_neurosim_cost_model():
    from repro.core.neurosim import kan_cost

    cand = tune.Candidate(grid_size=8, voltage_bits=3)
    m = tune.evaluate_candidate(None, cand, dims=(17, 1, 14))
    ref = kan_cost((17, 1, 14), 8, 3, 8, cand.input_gen(), 128, 8)
    for k, v in ref.items():
        assert m[k] == v
    assert "accuracy" not in m


def test_sam_candidates_use_the_acim_backend_with_placement(task):
    """SAM changes nothing but the IR-drop exposure: same cost, valid acc."""
    base = tune.Candidate(grid_size=5, voltage_bits=4)
    sam = dataclasses.replace(base, use_sam=True)
    m0 = tune.evaluate_candidate(task, base, acim_seeds=1)
    m1 = tune.evaluate_candidate(task, sam, acim_seeds=1)
    for k in ("area_mm2", "energy_pj", "latency_ns"):
        assert m0[k] == m1[k]
    assert 0.0 <= m1["accuracy"] <= 1.0
    # deterministic: same candidate, same seeds -> same accuracy
    assert m1 == tune.evaluate_candidate(task, sam, acim_seeds=1)


# ----------------------------------------------------------------------------
# Tile autotuner
# ----------------------------------------------------------------------------


def _kan1_dep(task):
    return tune.deploy_candidate(task, tune.Candidate(grid_size=5))


def test_tile_candidates_valid_and_bit_exact(task):
    _, _, dep = _kan1_dep(task)
    res = tune.tune_tiles(dep, batch=32, max_candidates=8, seed=0,
                          register=False)
    kept = [t for t in res.trials if t.valid]
    assert len(kept) >= 4
    # every retained candidate respects the geometric invariants ...
    for t in kept:
        plan = make_pipeline_plan(res.bucket, res.dims, res.specs,
                                  residual_raw=res.residual_raw,
                                  tile_overrides=t.overrides)
        validate_plan(plan)
        # overrides never change the padded dims (weights stay valid)
        for lp, hp in zip(plan.layers, res.heuristic_plan.layers):
            assert (lp.fp, lp.op) == (hp.fp, hp.op)
    # ... and every candidate that may win is bit-exact vs the heuristic
    assert all(t.exact for t in kept if np.isfinite(t.score))
    assert any(t.exact for t in kept)
    # the chosen plan reproduces the heuristic output bit-exactly
    x = jax.random.uniform(jax.random.PRNGKey(2), (9, res.dims[0]),
                           minval=-1.0, maxval=1.0)
    y_heur = kan_network_deploy_apply(dep, x, interpret=True)
    codes = jnp.asarray(
        np.random.default_rng(0).integers(
            0, res.specs[0].num_codes, size=(res.bucket, res.dims[0])
        ), jnp.int32)
    y_a = kan_pipeline(codes, None, dep.layers, res.heuristic_plan,
                       interpret=True)
    y_b = kan_pipeline(codes, None, dep.layers, res.chosen_plan,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(y_a), np.asarray(y_b))
    assert y_heur.shape == (9, res.dims[-1])


def test_invalid_tile_overrides_are_rejected():
    spec = tune.Candidate(grid_size=5).spec()
    with pytest.raises(ValueError):  # bo does not divide op
        make_pipeline_plan(32, (17, 1, 14), (spec, spec),
                           tile_overrides=(32, 96, 32))
    with pytest.raises(ValueError):  # bf not a power of two
        make_pipeline_plan(32, (17, 1, 14), (spec, spec),
                           tile_overrides=(32, 128, 24))
    with pytest.raises(ValueError):  # bb not a multiple of 8
        make_pipeline_plan(32, (17, 1, 14), (spec, spec),
                           tile_overrides=(12, 128, 32))
    with pytest.raises(ValueError):  # per-layer bb must agree
        make_pipeline_plan(32, (17, 1, 14), (spec, spec),
                           tile_overrides=((8, 128, 32), (16, 128, 128)))


def test_clearing_unregistered_overrides_does_not_invalidate(task):
    """A heuristic-won tune (or artifact with overrides=null) must not cost
    consumers already serving the geometry a plan rebuild or retrace."""
    _, _, dep = _kan1_dep(task)
    x = jax.random.uniform(jax.random.PRNGKey(4), (8, 17),
                           minval=-1.0, maxval=1.0)
    kan_network_deploy_apply(dep, x, interpret=True, backend="pallas")
    stats0 = runtime.cache_stats()
    runtime.PLAN_CACHE.set_tile_overrides(
        tuple(dep.dims), tuple(dep.specs), dep.residual_raw, None
    )
    kan_network_deploy_apply(dep, x, interpret=True, backend="pallas")
    stats1 = runtime.cache_stats()
    assert stats1["traces"] == stats0["traces"]
    assert stats1["hits"] == stats0["hits"] + 1


def test_tuned_plan_is_picked_up_without_retracing_consumers(task):
    _, _, dep = _kan1_dep(task)
    # force a non-heuristic winner deterministically: prefer the smallest
    # batch block (the heuristic picks the largest)
    res = tune.tune_tiles(dep, batch=32, max_candidates=8, seed=0,
                          register=True, warm=True,
                          score_fn=lambda p: p.layers[0].bb)
    assert res.tuned and res.registered
    assert res.chosen_overrides[0][0] < res.heuristic_plan.layers[0].bb
    # the registry serves the tuned plan to every plan resolution
    tuned_plan = runtime.PLAN_CACHE.plan(
        res.bucket, res.dims, res.specs, residual_raw=res.residual_raw
    )
    assert tuned_plan == res.chosen_plan
    assert dep.replan(res.bucket).plan == res.chosen_plan
    # consumers hit the warm cache entry: zero NEW traces, bit-exact output
    traces0 = runtime.cache_stats()["traces"]
    x = jax.random.uniform(jax.random.PRNGKey(3), (32, res.dims[0]),
                           minval=-1.0, maxval=1.0)
    y_tuned = kan_network_deploy_apply(dep, x, interpret=True,
                                       backend="pallas")
    assert runtime.cache_stats()["traces"] == traces0
    runtime.PLAN_CACHE.set_tile_overrides(res.dims, res.specs,
                                          res.residual_raw, None)
    y_heur = kan_network_deploy_apply(dep, x, interpret=True,
                                      backend="pallas")
    np.testing.assert_array_equal(np.asarray(y_tuned), np.asarray(y_heur))


# ----------------------------------------------------------------------------
# Artifacts
# ----------------------------------------------------------------------------


def test_artifact_roundtrip_reproduces_the_deployment(task, tmp_path):
    res = tune.pareto_search(
        task, SPACE,
        config=tune.SearchConfig(budget=3, n_init=2, seed=3, acim_seeds=1),
    )
    chosen = tune.select_point(res.front)
    _, _, dep = tune.deploy_candidate(task, chosen.candidate)
    tile = tune.tune_tiles(dep, batch=16, max_candidates=6, seed=0,
                           register=True, warm=False,
                           score_fn=lambda p: p.layers[0].bb)
    assert tile.tuned
    art = tune.build_tuning_artifact(search=res, chosen=chosen, tile=tile,
                                     task=task.name)
    path = tmp_path / "artifact.json"
    tune.save_tuning_artifact(str(path), art)

    runtime.reset_cache()  # cold runtime: only the file remains
    loaded = tune.load_tuning_artifact(str(path))
    assert loaded["version"] == tune.ARTIFACT_VERSION
    assert loaded["space_hash"] == tune.space_hash(SPACE)
    assert loaded["seed"] == 3
    resolved = tune.apply_tuning_artifact(loaded)
    # the chosen point and the tuned plan both survive the round trip
    assert resolved["candidate"] == chosen.candidate
    assert resolved["spec"] == chosen.candidate.spec()
    assert resolved["plan"] == tile.chosen_plan
    # and a fresh deployment under the reloaded artifact is bit-identical
    _, _, dep2 = tune.deploy_candidate(task, resolved["candidate"])
    x = jax.random.uniform(jax.random.PRNGKey(5), (10, task.dims[0]),
                           minval=-1.0, maxval=1.0)
    y1 = kan_network_deploy_apply(dep, x, interpret=True)
    y2 = kan_network_deploy_apply(dep2, x, interpret=True)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_artifact_schema_validation(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"kind": "something-else"}))
    with pytest.raises(ValueError):
        tune.load_tuning_artifact(str(bad))
    newer = tmp_path / "newer.json"
    newer.write_text(json.dumps({"kind": tune.ARTIFACT_KIND,
                                 "version": tune.ARTIFACT_VERSION + 1}))
    with pytest.raises(ValueError):
        tune.load_tuning_artifact(str(newer))
    with pytest.raises(ValueError):
        tune.save_tuning_artifact(str(tmp_path / "x.json"),
                                  {"kind": "nope"})


def test_candidate_and_space_serialization():
    cand = tune.Candidate(grid_size=8, voltage_bits=5, use_sam=True)
    assert tune.candidate_from_dict(cand.to_dict()) == cand
    # hash is stable across equal spaces, sensitive to axis changes
    assert tune.space_hash(SPACE) == tune.space_hash(
        tune.DesignSpace(grid_size=(3, 5, 8), voltage_bits=(3, 4, 5),
                         array_rows=(128,)))
    assert tune.space_hash(SPACE) != tune.space_hash(tune.DesignSpace())
    # invalid candidates are structurally rejected by the space
    assert not SPACE.is_valid(tune.Candidate(grid_size=200, n_bits=6))
    assert not SPACE.is_valid(tune.Candidate(voltage_bits=9))
