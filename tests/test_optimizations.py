"""Correctness of the §Perf optimizations: head padding, fast basis,
custom KAN-FFN VJP — each must be a pure layout/schedule change."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.core.bspline import bspline_basis, bspline_basis_fast
from repro.models import layers as L
from repro.models.model import decode_step, forward, init_params, prefill


@pytest.mark.parametrize("gk", [(5, 3), (8, 3), (16, 2), (4, 1), (68, 3)])
def test_fast_basis_equals_cox_de_boor(gk):
    g, k = gk
    x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, 257), jnp.float32)
    a = bspline_basis(x, -1.0, 1.0, g, k)
    b = bspline_basis_fast(x, -1.0, 1.0, g, k)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_head_padding_preserves_function_at_init():
    """Padded physical heads must not change logits (zero wo rows)."""
    cfg0 = smoke_config("qwen2.5-14b")
    cfg1 = dataclasses.replace(cfg0, head_pad_multiple=8)  # 4 -> 8 heads
    assert cfg1.phys_heads == 8 and cfg0.phys_heads == 4
    key = jax.random.PRNGKey(0)
    p1 = init_params(key, cfg1)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg0.vocab_size)}
    out1 = forward(p1, batch, cfg1)
    # zeroing the padded q/wo slots by hand must give the same output
    def zero_pad(leaf_path_ok):
        pass
    # the padded wo rows are zero at init => the extra heads contribute 0.
    # verify by also zeroing their wq columns (must be a no-op):
    def zero_extra_wq(d):
        if isinstance(d, dict):
            return {k: zero_extra_wq(v) for k, v in d.items()}
        return d
    p2 = jax.tree_util.tree_map_with_path(
        lambda kp, x: x.at[..., cfg0.num_heads:, :].set(0.0)
        if "wq" in "/".join(str(getattr(k, "key", k)) for k in kp)
        and x.ndim >= 3 and x.shape[-2] == 8 else x,
        p1,
    )
    out2 = forward(p2, batch, cfg1)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_padded_heads_serving_consistency():
    cfg = dataclasses.replace(smoke_config("qwen2.5-14b"), head_pad_multiple=8)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    b, s = 2, 20
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    full = forward(params, batch, cfg)
    _, cache = prefill(params, {"tokens": batch["tokens"][:, :s - 1]}, cfg,
                       max_len=s + 4)
    logits, _ = decode_step(params, cache, batch["tokens"][:, s - 1],
                            jnp.full((b,), s - 1, jnp.int32), cfg)
    scale = float(jnp.abs(full).max())
    assert float(jnp.abs(logits - full[:, s - 1]).max()) < 2e-3 * scale + 1e-4


def test_kan_ffn_custom_vjp_matches_autodiff():
    cfg = smoke_config("qwen2.5-14b").kan_variant(grid=8)
    key = jax.random.PRNGKey(0)
    p = L.init_ffn(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32) * 0.5
    spec = L.kan_ffn_spec(cfg)
    hgk = (spec.hi, spec.grid_size, spec.order)

    def f_custom(c, x):
        return jnp.sum(L._spline_mm(x, c, spec.lo, hgk, "t") ** 2)

    def f_ref(c, x):
        basis = bspline_basis_fast(jnp.tanh(x.astype(jnp.float32)),
                                   spec.lo, spec.hi, spec.grid_size, spec.order)
        return jnp.sum(jnp.einsum("bsfn,fno->bso", basis.astype(c.dtype), c) ** 2)

    gc1, gx1 = jax.grad(f_custom, argnums=(0, 1))(p["c1"], x)
    gc2, gx2 = jax.grad(f_ref, argnums=(0, 1))(p["c1"], x)
    np.testing.assert_allclose(np.asarray(gc1), np.asarray(gc2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2),
                               rtol=1e-4, atol=1e-5)


def test_kan_ffn_forward_finite_and_trains():
    cfg = smoke_config("qwen2.5-14b").kan_variant(grid=8)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = {
        "tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
    }
    from repro.models.model import loss_fn
    from repro.train.optimizer import adamw, apply_updates

    opt = adamw(3e-3)
    st = opt.init(params)

    @jax.jit
    def step(params, st):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        u, st = opt.update(grads, st, params)
        return apply_updates(params, u), st, loss

    losses = []
    for _ in range(3):
        params, st, loss = step(params, st)
        losses.append(float(loss))
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]
