"""ASP-KAN-HAQ: the paper's alignment/symmetry/powergap invariants."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.asp_quant import (
    ASPQuantSpec,
    build_lut,
    dense_basis_from_codes,
    hemi_fold,
    hemi_unfold,
    lookup_active,
    max_ld,
    pact_basis_tables,
    pact_dense_basis,
    quantize_input,
    quantized_dense_basis,
)
from repro.core.bspline import bspline_basis


def test_max_ld_law():
    # eq (6): G * 2**LD <= 2**n, LD maximal
    assert max_ld(5, 8) == 5      # 5*32=160 <= 256 < 5*64
    assert max_ld(8, 8) == 5      # 8*32=256 <= 256
    assert max_ld(68, 8) == 1
    assert max_ld(68, 10) == 3
    assert max_ld(257, 8) == -1   # unsatisfiable


@settings(max_examples=60, deadline=None)
@given(g=st.integers(1, 128), n=st.integers(4, 12))
def test_max_ld_is_maximal_and_feasible(g, n):
    ld = max_ld(g, n)
    if ld < 0:
        assert g > 2**n
    else:
        assert g * 2**ld <= 2**n
        assert g * 2 ** (ld + 1) > 2**n


@pytest.mark.parametrize("g,n", [(5, 8), (8, 8), (16, 8), (64, 8), (68, 10), (3, 6)])
def test_alignment_shared_lut_equals_float_basis(g, n):
    """THE alignment property: on-grid inputs, the single shared LUT
    reproduces every B_i exactly (up to LUT value quantization)."""
    spec = ASPQuantSpec(grid_size=g, order=3, n_bits=n, lut_bits=16, lo=-1.0, hi=1.0)
    e = build_lut(spec)
    lut = jnp.asarray(e["lut_q"] * e["scale"], jnp.float32)
    codes = jnp.arange(spec.num_codes, dtype=jnp.int32)
    dense = dense_basis_from_codes(codes, lut, spec)
    x = spec.lo + codes.astype(jnp.float32) * spec.code_step
    ref = bspline_basis(x, spec.lo, spec.hi, g, 3)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ref), atol=3e-4)


def test_hemi_fold_halves_storage_and_roundtrips():
    for g in [5, 8, 16]:
        spec = ASPQuantSpec(grid_size=g, order=3, n_bits=8, lo=0.0, hi=1.0)
        e = build_lut(spec)
        total = (spec.order + 1) * spec.codes_per_interval
        assert len(e["hemi"]) == total // 2 + 1  # ~50% of the full table
        flat = hemi_unfold(e["hemi"], spec)
        refolded = hemi_fold(
            np.stack(
                [flat[(spec.order - d) * spec.codes_per_interval:
                      (spec.order - d + 1) * spec.codes_per_interval]
                 for d in range(spec.order + 1)], axis=1),
            spec,
        )
        np.testing.assert_array_equal(refolded, e["hemi"])


@settings(max_examples=30, deadline=None)
@given(
    g=st.integers(2, 40),
    order=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_quantized_basis_close_to_float(g, order, seed):
    try:
        spec = ASPQuantSpec(grid_size=g, order=order, n_bits=10, lo=-1.0, hi=1.0)
    except ValueError:
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 1, size=128), jnp.float32)
    qb = np.asarray(quantized_dense_basis(x, spec))
    fb = np.asarray(bspline_basis(x, -1.0, 1.0, g, order))
    # error bounded by input-quantization step (Lipschitz const of bump < 2/h_code)
    assert np.abs(qb - fb).max() < 2.5 * spec.code_step / spec.knot_step + 1e-2


def test_powergap_bit_split_consistency():
    spec = ASPQuantSpec(grid_size=8, order=3, n_bits=8, lo=0.0, hi=1.0)
    e = build_lut(spec)
    lut = jnp.asarray(e["lut"], jnp.float32)
    codes = jnp.arange(spec.num_codes, dtype=jnp.int32)
    g_idx, vals = lookup_active(codes, lut, spec)
    # global bits = interval index, exactly floor(x / knot_step)
    x = np.asarray(codes) * spec.code_step
    np.testing.assert_array_equal(
        np.asarray(g_idx), np.floor(x / spec.knot_step).astype(np.int32)
    )
    assert vals.shape == (spec.num_codes, spec.order + 1)


def test_pact_baseline_needs_distinct_tables():
    """Misaligned grids: every B_i's code->value table is distinct (the
    motivation for per-B_i LUTs in the conventional design)."""
    spec = ASPQuantSpec(grid_size=5, order=3, n_bits=8, lo=0.0, hi=1.0)
    tables = pact_basis_tables(spec)
    assert len({tables[i].tobytes() for i in range(spec.num_basis)}) == spec.num_basis
    x = jnp.linspace(0.0, 1.0, 97)
    pb = np.asarray(pact_dense_basis(x, spec, tables))
    fb = np.asarray(bspline_basis(x, 0.0, 1.0, 5, 3))
    assert np.abs(pb - fb).max() < 0.02  # baseline is accurate, just costly


def test_signed_variant_affine_map():
    spec = ASPQuantSpec(grid_size=5, order=3, n_bits=8, lo=-1.0, hi=1.0, signed=True)
    x = jnp.asarray([-1.0, 0.0, 1.0 - 1e-6])
    codes = np.asarray(quantize_input(x, spec))
    assert codes[0] == 0 and codes[-1] == spec.num_codes - 1
    assert codes[1] == spec.num_codes // 2
