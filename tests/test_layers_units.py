"""Layer-level unit tests: SSD chunked == naive recurrence, RG-LRU scan ==
step-by-step, MoE dispatch properties, chunked attention == direct, RoPE."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models import layers as L


def test_ssd_chunked_equals_naive_recurrence():
    """The SSD chunked algorithm must equal the sequential SSM recurrence."""
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 32, 3, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.3
    b = jax.random.normal(ks[3], (B, S, N)) * 0.5
    c = jax.random.normal(ks[4], (B, S, N)) * 0.5

    for chunk in (4, 8, 16, 32):
        y, final = L._ssd_chunked(x, dt, a_log, b, c, chunk)
        # naive: h_t = exp(dt*A) h_{t-1} + dt*x_t b_t^T ; y_t = c_t . h_t
        a = -jnp.exp(a_log)
        h = jnp.zeros((B, H, N, P))
        ys = []
        for t in range(S):
            da = jnp.exp(dt[:, t] * a)  # (B,H)
            h = h * da[..., None, None] + jnp.einsum(
                "bn,bhp->bhnp", b[:, t], x[:, t] * dt[:, t][..., None])
            ys.append(jnp.einsum("bn,bhnp->bhp", c[:, t], h))
        y_naive = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_naive),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(final), np.asarray(h),
                                   rtol=2e-4, atol=2e-4)


def test_rglru_scan_equals_stepwise():
    cfg = smoke_config("recurrentgemma-9b")
    key = jax.random.PRNGKey(1)
    p = L.init_rglru(key, cfg)
    x = jax.random.normal(key, (2, 12, cfg.d_model), jnp.float32) * 0.3
    y_full, state_f = L.rglru_prefill(p, x, cfg)
    # step-by-step decode from zero state
    st = L.init_rglru_state(cfg, 2)
    outs = []
    for t in range(12):
        y, st = L.rglru(p, x[:, t:t + 1], cfg, state=st)
        outs.append(y)
    y_steps = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_steps),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state_f["h"]), np.asarray(st["h"]),
                               rtol=2e-3, atol=2e-3)


def test_moe_routes_to_topk_and_respects_capacity():
    cfg = dataclasses.replace(smoke_config("olmoe-1b-7b"),
                              moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(2)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    out = L.moe(p, x, cfg)
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())
    # with huge capacity, output must equal the dense (loop) reference
    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    topv, topi = jax.lax.top_k(logits, cfg.num_experts_per_tok)
    gates = jax.nn.softmax(topv, axis=-1)
    xt = x.reshape(-1, cfg.d_model)
    ref = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.num_experts_per_tok):
            e = int(topi[t, j])
            h = jax.nn.silu(xt[t] @ p["wg"][e]) * (xt[t] @ p["wi"][e])
            acc = acc + gates[t, j] * (h @ p["wo"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_chunked_attention_equals_direct():
    cfg = smoke_config("qwen2.5-14b")
    key = jax.random.PRNGKey(3)
    B, S, Hq, Hkv, D = 2, 4096, 4, 2, 16  # S multiple of chunk -> scan path
    q = jax.random.normal(key, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    out_scan = L._sdpa(q, k, v, cfg, "global")
    # direct single-chunk path via temporarily large chunk
    orig = L.ATTN_CHUNK
    try:
        L.ATTN_CHUNK = 10**9
        out_direct = L._sdpa(q, k, v, cfg, "global")
    finally:
        L.ATTN_CHUNK = orig
    np.testing.assert_allclose(np.asarray(out_scan), np.asarray(out_direct),
                               rtol=1e-4, atol=1e-4)


def test_local_attention_window_semantics():
    cfg = dataclasses.replace(smoke_config("mixtral-8x7b"), window_size=4)
    key = jax.random.PRNGKey(4)
    B, S, H, D = 1, 10, 2, 8
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(key, (B, S, H, D))
    v = jax.random.normal(key, (B, S, H, D))
    out = L._sdpa(q, k, v, cfg, "local")
    # position 9 must not attend to position <= 5: zeroing those k/v rows
    k2 = k.at[:, :6].set(100.0)
    v2 = v.at[:, :6].set(100.0)
    out2 = L._sdpa(q, k2, v2, cfg, "local")
    np.testing.assert_allclose(np.asarray(out[:, 9]), np.asarray(out2[:, 9]),
                               rtol=1e-5)
    # but position 5 WOULD see them
    assert not np.allclose(np.asarray(out[:, 5]), np.asarray(out2[:, 5]))


def test_rope_rotation_invariance():
    """RoPE: dot(q_m, k_n) depends only on (m - n)."""
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(6), (1, 1, 1, 16))
    def dot_at(m, n):
        qm = L.rope(q, jnp.array([[m]]), 10000.0)
        kn = L.rope(k, jnp.array([[n]]), 10000.0)
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-3


def test_softcap():
    x = jnp.asarray([-100.0, 0.0, 100.0])
    y = L.softcap(x, 30.0)
    assert float(jnp.abs(y).max()) <= 30.0
    np.testing.assert_allclose(np.asarray(L.softcap(x, 0.0)), np.asarray(x))
