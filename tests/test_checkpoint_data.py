"""Fault-tolerance substrate: checkpoint atomicity/rotation/restore,
data-pipeline determinism and seekability."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.lm_data import DataConfig, global_batch_at_step, host_batch_at_step
from repro.train.checkpoint import Checkpointer, latest_step, load_pytree, save_pytree


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(5), "c": jnp.float32(3.5)},
    }


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    p = str(tmp_path / "ckpt")
    save_pytree(t, p)
    t2 = load_pytree(p, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_partial_dir_visible(tmp_path):
    """A tmp dir from a crashed writer must not count as a checkpoint."""
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_5.tmp-1234"))
    assert latest_step(d) is None
    ck = Checkpointer(d, keep=2)
    ck.save(7, _tree(), blocking=True)
    assert latest_step(d) == 7


def test_keep_n_rotation(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in [10, 20, 30, 40]:
        ck.save(s, _tree(s), blocking=True)
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert steps == [30, 40]


def test_restore_latest_and_async(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    t = _tree(1)
    ck.save(3, t)          # async
    ck.wait()
    restored, step = ck.restore_latest(t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_elastic_restore_with_new_sharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore under a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path), keep=1)
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, t, blocking=True)
    from repro.launch.mesh import _make_mesh

    mesh = _make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ck.restore_latest(t, shardings=sh)
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))


# ---------------------------------------------------------------------------


def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    b1 = global_batch_at_step(cfg, 17)
    b2 = global_batch_at_step(cfg, 17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = global_batch_at_step(cfg, 18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # shapes + shifted targets
    assert b1["tokens"].shape == (8, 64)
    assert (b1["tokens"] < 1000).all() and (b1["tokens"] >= 0).all()


def test_data_host_sharding_shapes():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    h0 = host_batch_at_step(cfg, 5, host_id=0, num_hosts=4)
    h1 = host_batch_at_step(cfg, 5, host_id=1, num_hosts=4)
    assert h0["tokens"].shape == (2, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])  # distinct shards
    # determinism per host
    np.testing.assert_array_equal(
        h0["tokens"], host_batch_at_step(cfg, 5, 0, 4)["tokens"]
    )
