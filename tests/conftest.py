"""Shared test config; makes ``hypothesis`` optional.

With ``hypothesis`` installed (see requirements-dev.txt) the property-based
tests run as written.  On a bare interpreter a small deterministic shim is
registered under the ``hypothesis`` / ``hypothesis.strategies`` module names
BEFORE the test modules import them: each ``@given`` test then runs a fixed
number of cases sampled from a per-test seeded RNG, so the four
property-based modules (test_asp_quant, test_bspline, test_kernels_cim_mac,
test_kernels_kan_spline) still collect and exercise their invariants.

The shim implements only what this suite uses — ``given``, ``settings``,
``strategies.integers``, ``strategies.sampled_from`` (plus a few cheap
extras) — and is deliberately deterministic: same test name, same cases.
Set ``HYPOTHESIS_SHIM_MAX_EXAMPLES`` to change the per-test case budget.
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types
import zlib

try:  # pragma: no cover - exercised in the hypothesis-installed CI leg
    import hypothesis  # noqa: F401

    HYPOTHESIS_IS_SHIM = False
except ImportError:
    HYPOTHESIS_IS_SHIM = True

    _DEFAULT_EXAMPLES = int(os.environ.get("HYPOTHESIS_SHIM_MAX_EXAMPLES", "6"))

    class _Strategy:
        """A deterministic sampler standing in for a hypothesis strategy."""

        def __init__(self, sample):
            self.sample = sample

        def map(self, f):
            return _Strategy(lambda rng: f(self.sample(rng)))

        def filter(self, pred, _tries: int = 100):
            def sample(rng):
                for _ in range(_tries):
                    v = self.sample(rng)
                    if pred(v):
                        return v
                raise ValueError("shim filter found no satisfying value")

            return _Strategy(sample)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _lists(elem, min_size=0, max_size=8, **_kw):
        return _Strategy(
            lambda rng: [
                elem.sample(rng) for _ in range(rng.randint(min_size, max_size))
            ]
        )

    def _settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._shim_max_examples = max_examples
            return fn

        return deco

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                declared = getattr(wrapper, "_shim_max_examples", None)
                n = _DEFAULT_EXAMPLES if declared is None \
                    else min(declared, _DEFAULT_EXAMPLES)
                # per-test deterministic seed: same name -> same cases
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(max(n, 1)):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items()
                    if name not in strategies
                ]
            )
            return wrapper

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.__doc__ = "Deterministic fallback shim (see tests/conftest.py)."
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.floats = _floats
    _st.booleans = _booleans
    _st.lists = _lists
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
