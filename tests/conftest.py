"""Shared test config: hypothesis fallback + the golden-parity harness.

Two roles:

  * makes ``hypothesis`` optional.  With ``hypothesis`` installed (see
    requirements-dev.txt) the property-based tests run as written; on a
    bare interpreter a small deterministic shim is registered under the
    ``hypothesis`` / ``hypothesis.strategies`` module names BEFORE the test
    modules import them — each ``@given`` test then runs a fixed number of
    cases from a per-test seeded RNG.  The shim implements only what this
    suite uses (``given``, ``settings``, ``strategies.integers``,
    ``strategies.sampled_from`` plus a few cheap extras).  Set
    ``HYPOTHESIS_SHIM_MAX_EXAMPLES`` to change the per-test case budget.

  * the shared **golden-parity harness**: one deployed KAN1 bundle per bit
    allocation with its expected output + boundary codes captured ONCE on
    the unsharded fused pipeline (``golden_parity`` fixture), plus the
    ``run_pair`` / ``assert_bit_exact`` helpers and the idempotent
    ``acim-quiet`` backend registration that test_runtime / test_kvpool /
    test_spec_decode / test_mixed_precision all share (import them with
    ``from conftest import ...``).  The serving suites also share one
    session-scoped qwen2.5-14b KAN-FFN param tree (``kan_setup``).
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types
import zlib

try:  # pragma: no cover - exercised in the hypothesis-installed CI leg
    import hypothesis  # noqa: F401

    HYPOTHESIS_IS_SHIM = False
except ImportError:
    HYPOTHESIS_IS_SHIM = True

    _DEFAULT_EXAMPLES = int(os.environ.get("HYPOTHESIS_SHIM_MAX_EXAMPLES", "6"))

    class _Strategy:
        """A deterministic sampler standing in for a hypothesis strategy."""

        def __init__(self, sample):
            self.sample = sample

        def map(self, f):
            return _Strategy(lambda rng: f(self.sample(rng)))

        def filter(self, pred, _tries: int = 100):
            def sample(rng):
                for _ in range(_tries):
                    v = self.sample(rng)
                    if pred(v):
                        return v
                raise ValueError("shim filter found no satisfying value")

            return _Strategy(sample)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _lists(elem, min_size=0, max_size=8, **_kw):
        return _Strategy(
            lambda rng: [
                elem.sample(rng) for _ in range(rng.randint(min_size, max_size))
            ]
        )

    def _settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._shim_max_examples = max_examples
            return fn

        return deco

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                declared = getattr(wrapper, "_shim_max_examples", None)
                n = _DEFAULT_EXAMPLES if declared is None \
                    else min(declared, _DEFAULT_EXAMPLES)
                # per-test deterministic seed: same name -> same cases
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(max(n, 1)):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items()
                    if name not in strategies
                ]
            )
            return wrapper

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.__doc__ = "Deterministic fallback shim (see tests/conftest.py)."
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.floats = _floats
    _st.booleans = _booleans
    _st.lists = _lists
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


# ----------------------------------------------------------------------------
# pytest config
# ----------------------------------------------------------------------------


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running suite (kept in CI; deselect locally with "
        '-m "not slow")',
    )


# ----------------------------------------------------------------------------
# golden-parity harness (shared by the runtime/serving/mixed-precision suites)
# ----------------------------------------------------------------------------

# the (backend, bits) grid the parity tests sweep; mesh cells are built per
# test from the host's device count.  8 = the uniform legacy deployment,
# (8, 4)/(4, 4) = mixed / fully sub-8-bit int4-packed allocations.
GOLDEN_BITS = (8, (8, 4), (4, 4))
GOLDEN_BACKENDS = ("ref", "pallas", "acim-quiet")


def ensure_quiet_acim_backend() -> str:
    """Idempotently register the zero-noise acim executor as "acim-quiet".

    Quiet acim traces the same program as "pallas" (every non-ideality
    zeroed and compiled out), so its streams take part in every
    bit-identity acceptance.  Returns the backend name.
    """
    from repro import runtime
    from repro.runtime.executor import ACIMExecutor

    if "acim-quiet" not in runtime.available_backends():
        runtime.register_executor(
            "acim-quiet", ACIMExecutor(cim=runtime.quiet_cim_config())
        )
    return "acim-quiet"


def kan1_bundle(n_bits=8, batch=8, seed=0, grid=5):
    """Deploy the paper's KAN1 geometry at a (possibly mixed) bit allocation.

    Returns (kspec, qparams, dep).  ``n_bits`` may be an int or a per-layer
    tuple; layers at <= 4 bits deploy int4-packed.
    """
    import jax as _jax

    from repro.core.kan_layer import KANSpec, init_kan_network
    from repro.core.kan_network_deploy import (
        deploy_kan_network,
        quantize_kan_network,
    )

    kspec = KANSpec(dims=(17, 1, 14), grid_size=grid, n_bits=n_bits)
    key = _jax.random.PRNGKey(seed)
    qparams = quantize_kan_network(init_kan_network(key, kspec), kspec)
    dep = deploy_kan_network(qparams, kspec, batch=batch)
    return kspec, qparams, dep


def run_pair(dep, x, mesh, backend="pallas", **kw):
    """(unsharded pallas, sharded ``backend``) outputs + boundary codes."""
    from repro.core.kan_network_deploy import kan_network_deploy_apply

    y0, c0 = kan_network_deploy_apply(
        dep, x, interpret=True, backend="pallas", return_intermediates=True
    )
    y1, c1 = kan_network_deploy_apply(
        dep, x, interpret=True, backend=backend, mesh=mesh,
        return_intermediates=True, **kw
    )
    return (y0, c0), (y1, c1)


def assert_bit_exact(a, b):
    """Both (y, codes) pairs agree bitwise — outputs AND boundary codes."""
    import numpy as np

    (y0, c0), (y1, c1) = a, b
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
    assert len(c0) == len(c1)
    for x0, x1 in zip(c0, c1):
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x0))


import pytest  # noqa: E402  (after the shim install, by design)


@pytest.fixture(scope="session")
def kan_setup():
    """One qwen2.5-14b KAN-FFN smoke config + param tree for the serving
    suites (params are immutable jax arrays — safe to share)."""
    import jax as _jax

    from repro.configs.registry import smoke_config
    from repro.models.model import init_params

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    return cfg, init_params(_jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="session")
def golden_parity():
    """The golden-parity table: bits -> one deployed bundle + its expected
    output and boundary codes, captured once on the unsharded fused
    pipeline.  Every (backend, mesh, bits) parity cell replays against
    THESE arrays, so any backend- or mesh-dependent divergence shows up as
    a bitwise diff against a single source of truth.
    """
    import jax as _jax
    import numpy as np

    from repro.core.kan_network_deploy import kan_network_deploy_apply

    table = {}
    for bits in GOLDEN_BITS:
        kspec, qparams, dep = kan1_bundle(n_bits=bits, batch=16)
        x = _jax.random.uniform(_jax.random.PRNGKey(3), (13, 17),
                                minval=-1.0, maxval=1.0)
        y, codes = kan_network_deploy_apply(
            dep, x, interpret=True, backend="pallas",
            return_intermediates=True,
        )
        table[bits] = {
            "kspec": kspec,
            "qparams": qparams,
            "dep": dep,
            "x": x,
            "y": np.asarray(y),
            "codes": tuple(np.asarray(c) for c in codes),
        }
    return table
