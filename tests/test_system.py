"""End-to-end system behaviour tests for the paper's pipeline."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cim import CIMConfig
from repro.core.kan_layer import KANSpec
from repro.core.neurosim import (
    evaluate_accuracy,
    evaluate_accuracy_cim,
    train_kan,
)
from repro.data.knot import make_knot_dataset


@pytest.fixture(scope="module")
def trained_kan():
    xt, yt, xv, yv = make_knot_dataset(4096, 1024, seed=0, label_noise=0.04)

    def sched(step):
        t = jnp.minimum(step / 150.0, 1.0)
        return 1.5e-2 * 0.95 * (0.5 * (1 + jnp.cos(jnp.pi * t))) + 1e-3

    kspec = KANSpec(dims=(17, 1, 14), grid_size=8)
    params, hist = train_kan(kspec, xt, yt, xv, yv, epochs=80,
                             batch_size=2048, lr=sched)
    return kspec, params, (xt, yt, xv, yv)


def test_kan_learns_knot_task(trained_kan):
    kspec, params, (xt, yt, xv, yv) = trained_kan
    acc = evaluate_accuracy(params, xv, yv, kspec)
    assert acc > 0.55, acc  # far above 1/14 chance


def test_quantized_acim_accuracy_close_to_software(trained_kan):
    kspec, params, (xt, yt, xv, yv) = trained_kan
    sw = evaluate_accuracy(params, xv, yv, kspec)
    cim = CIMConfig(array_rows=128, adc_bits=10, ir_gamma=0.03,
                    sigma_ps_ref=0.05)
    hw = evaluate_accuracy_cim(params, xv, yv, kspec, cim,
                               jax.random.PRNGKey(0), use_sam=True,
                               calib_x=xt[:1024])
    assert sw - hw < 0.08, (sw, hw)


def test_sam_reduces_mac_error_on_trained_model(trained_kan):
    """KAN-SAM mechanism on the TRAINED model's real spline weights: the
    deterministic IR-drop MAC error must shrink under the SAM placement.
    (Accuracy-level protection is validated in benchmarks/fig12 with
    fully-trained models; the 80-epoch CI fixture is too noisy for a stable
    accuracy comparison.)"""
    from repro.core.asp_quant import dense_basis_from_codes, quantize_input
    from repro.core.cim import cim_matmul, ideal_matmul
    from repro.core.kan_layer import quantize_kan_layer
    from repro.core.sam import row_activation_weight, sam_permutation

    kspec, params, (xt, yt, xv, yv) = trained_kan
    spec = kspec.layer_spec()
    qp = quantize_kan_layer(params[0], spec)
    codes = quantize_input(jnp.asarray(xv[:512]), spec)
    basis = dense_basis_from_codes(codes, qp["lut"], spec)
    drives = basis.reshape(512, -1) / float(qp["lut_scale"])
    w_rows = qp["c_q"].astype(jnp.float32).reshape(drives.shape[1], -1)
    ideal = ideal_matmul(drives, w_rows)
    cim = CIMConfig(array_rows=512, adc_bits=10, ir_gamma=0.12,
                    sigma_ps_ref=0.0, deterministic=True)
    base = cim_matmul(drives, w_rows, cim, jax.random.PRNGKey(0),
                      x_max=255.0, adc_calibrate=True)
    rw = row_activation_weight(jnp.asarray(xt[:2048]), spec, 17)
    sam = cim_matmul(drives, w_rows, cim, jax.random.PRNGKey(0),
                     row_perm=sam_permutation(rw, cim.array_rows),
                     x_max=255.0, adc_calibrate=True)
    err_base = float(jnp.abs(base - ideal).mean())
    err_sam = float(jnp.abs(sam - ideal).mean())
    assert err_sam < err_base, (err_sam, err_base)


DRYRUN_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import smoke_config
    from repro.dist import sharding as shd
    from repro.train.train_state import init_state, make_train_step

    from repro.launch.mesh import _make_mesh

    mesh = _make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(smoke_config("{arch}"), microbatch=2)
    key = jax.random.PRNGKey(0)
    state_shape = jax.eval_shape(lambda: init_state(key, cfg))
    pspecs = {{
        "params": shd.param_pspecs(state_shape["params"], mesh),
        "opt": shd.opt_state_pspecs(state_shape["opt"], state_shape["params"], mesh),
        "step": P(), "good_steps": P(), "skipped_steps": P(),
    }}
    sh = shd.to_shardings(pspecs, mesh)
    batch = {{
        "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
        "targets": jax.ShapeDtypeStruct((4, 32), jnp.int32),
    }}
    if "whisper" in "{arch}":
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (4, cfg.enc_seq, cfg.d_model), jnp.float32)
    if "pixtral" in "{arch}":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (4, cfg.num_patches, cfg.patch_embed_dim), jnp.float32)
    bsh = jax.tree.map(
        lambda s: NamedSharding(mesh, P("data", *([None] * (len(s.shape) - 1)))),
        batch)
    with mesh:
        step = make_train_step(cfg)
        compiled = jax.jit(step, in_shardings=(sh, bsh),
                           out_shardings=(sh, None)).lower(state_shape, batch).compile()
    assert compiled.memory_analysis() is not None
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns per-device list
        ca = ca[0]
    print("OK", ca["flops"] > 0)
""")


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "olmoe-1b-7b", "mamba2-370m"])
def test_dryrun_tiny_mesh_subprocess(arch):
    """lower+compile on an 8-device fake mesh (separate process so the
    device-count flag doesn't leak into this test session)."""
    code = DRYRUN_SNIPPET.format(arch=arch)
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           # keep platform pinning (containers that don't pin hang probing
           # for accelerator backends at jax init)
           **{k: v for k, v in os.environ.items() if k.startswith("JAX_")}}
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=420, env=env,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
