"""Optimizers, sharding rules, gradient compression, train loop."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.train.optimizer import adafactor, adamw, apply_updates, clip_by_global_norm, sgdm


@pytest.mark.parametrize("make", [lambda: adamw(0.1), lambda: adafactor(0.5),
                                  lambda: sgdm(0.05)])
def test_optimizer_converges_quadratic(make):
    opt = make()
    params = {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array(4.0)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        u, state = opt.update(g, state, params)
        params = apply_updates(params, u)
    assert float(loss(params)) < 0.01 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(0.1)
    params = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((7,))}
    st = opt.init(params)
    assert st["v"]["big"]["vr"].shape == (64,)
    assert st["v"]["big"]["vc"].shape == (32,)
    assert st["v"]["vec"]["v"].shape == (7,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def _fake_mesh(shape=(2, 2), axes=("data", "model")):
    # abstract mesh over CPU devices repeated — only specs are inspected
    import numpy as np
    from jax.sharding import Mesh

    devs = np.array(jax.devices() * (int(np.prod(shape)) // len(jax.devices()) + 1))
    return Mesh(devs[: int(np.prod(shape))].reshape(shape), axes)


def test_param_pspecs_roles():
    from repro.configs.registry import smoke_config
    from repro.dist.sharding import param_pspecs
    from repro.models.model import init_params

    cfg = smoke_config("qwen2.5-14b")
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    mesh = _fake_mesh((1, 2))
    specs = param_pspecs(params, mesh, fsdp=False)
    # embed (V=256, D=64): vocab on model
    assert specs["embed"] == P("model", None)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    # every attention wq sharded on heads axis (index ndim-2)
    for kp, spec in flat:
        path = "/".join(str(getattr(k, "key", k)) for k in kp)
        if path.endswith("attn/wq"):
            assert "model" in spec, path


def test_fsdp_and_zero1_do_not_conflict():
    from repro.configs.registry import smoke_config
    from repro.dist.sharding import opt_state_pspecs, param_pspecs
    from repro.models.model import init_params
    from repro.train.train_state import init_state

    cfg = dataclasses.replace(smoke_config("qwen2.5-14b"), d_model=64)
    mesh = _fake_mesh((2, 2))
    state = jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0), cfg))
    ps = param_pspecs(state["params"], mesh)
    os_ = opt_state_pspecs(state["opt"], state["params"], mesh, zero1=True)

    def check(spec):
        names = [n for n in jax.tree.leaves(spec, is_leaf=lambda x: x is not None)]
        flat = [x for p in (spec or []) for x in
                ((p,) if not isinstance(p, tuple) else p) if p is not None]
        assert len(flat) == len(set(flat)), spec

    for spec in jax.tree.leaves(ps, is_leaf=lambda x: isinstance(x, P)):
        check(spec)
    for spec in jax.tree.leaves(os_, is_leaf=lambda x: isinstance(x, P)):
        check(spec)


def test_batch_pspec_fallbacks():
    from repro.dist.sharding import batch_pspec

    mesh = _fake_mesh((2, 2))
    assert batch_pspec(mesh, 4) == P(("data",))
    assert batch_pspec(mesh, 2) == P("data")
    assert batch_pspec(mesh, 1) == P(None)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_int8_allreduce_mean():
    import os
    from repro.dist.compress import compressed_grad_sync, init_error_feedback
    from jax.sharding import Mesh

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run under forked XLA device count)")


def test_quantize_error_feedback_reduces_bias():
    """Error feedback: repeated compression of the same gradient must not
    lose the residual (it accumulates and re-enters)."""
    from repro.dist.compress import _quantize

    g = jnp.asarray(np.random.default_rng(0).normal(size=(256,)) * 1e-3)
    e = jnp.zeros_like(g)
    total_applied = jnp.zeros_like(g)
    for _ in range(50):
        q, s = _quantize(g + e)
        deq = q.astype(jnp.float32) * s
        e = (g + e) - deq
        total_applied += deq
    mean_applied = total_applied / 50
    assert float(jnp.abs(mean_applied - g).max()) < 5e-6


# ---------------------------------------------------------------------------
# deployed-KAN bundle shipping (gather -> compress -> scatter)
# ---------------------------------------------------------------------------


def _kan_bundle(batch=8):
    from repro.core.kan_layer import KANSpec, init_kan_network
    from repro.core.kan_network_deploy import (
        deploy_kan_network,
        quantize_kan_network,
    )

    kspec = KANSpec(dims=(17, 17, 17), grid_size=5)
    qparams = quantize_kan_network(
        init_kan_network(jax.random.PRNGKey(0), kspec), kspec
    )
    return deploy_kan_network(qparams, kspec, batch=batch)


def test_deployed_kan_compress_roundtrip_sharded():
    """Checkpoint shipping for sharded deployments: gather a (placed)
    bundle, int8-compress it, scatter it back onto a mesh — outputs must
    match the original within the int8 weight-codec error, the scattered
    bundle must carry the target placement, and a geometry mismatch must
    refuse to decode."""
    from repro.core.kan_network_deploy import (
        kan_network_deploy_apply,
        place_deployed_kan,
    )
    from repro.dist.compress import (
        compress_deployed_kan,
        decompress_deployed_kan,
    )
    from repro.launch.mesh import make_local_mesh

    dep = _kan_bundle()
    multi = len(jax.devices()) >= 2
    mesh = make_local_mesh(1, 2) if multi else make_local_mesh(1, 1)
    placed = place_deployed_kan(dep, mesh)  # gather side starts SHARDED

    payload = compress_deployed_kan(placed)
    for entry, lw in zip(payload["layers"], dep.layers):
        assert entry["wc"][0].dtype == np.int8  # the bulk ships as int8
        assert entry["wc"][0].shape == lw["wc"].shape  # gathered to global

    dep2 = decompress_deployed_kan(payload, dep, mesh=mesh)
    assert dep2.placement is mesh

    x = jax.random.uniform(jax.random.PRNGKey(1), (6, 17), minval=-1, maxval=1)
    y0 = kan_network_deploy_apply(dep, x, interpret=True)
    y1 = kan_network_deploy_apply(dep2, x, interpret=True)  # sharded exec
    # the int8 weight codec's error envelope: boundary re-coding can amplify
    # a per-weight half-LSB, so a few percent of the output scale — far
    # below anything a scatter/transpose/scale bug would produce
    scale = float(jnp.abs(y0).max()) + 1e-6
    assert float(jnp.abs(y1 - y0).max()) < 5e-2 * scale

    # host-side decode (no mesh) agrees with the scattered one (model-
    # sharded accumulation may re-tile, so tolerance rather than bits)
    dep3 = decompress_deployed_kan(payload, dep, mesh=None)
    assert dep3.placement is None
    y2 = kan_network_deploy_apply(dep3, x, interpret=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               atol=1e-5, rtol=1e-5)

    other = _kan_bundle(batch=4)
    import dataclasses as _dc

    wrong = _dc.replace(other, dims=(17, 17, 14))
    with pytest.raises(ValueError):
        decompress_deployed_kan(payload, wrong)


# ---------------------------------------------------------------------------
# train loop (smoke config end-to-end with restart)
# ---------------------------------------------------------------------------


def test_train_loop_checkpoint_restart(tmp_path):
    from repro.configs.registry import smoke_config
    from repro.data.lm_data import DataConfig
    from repro.train.loop import TrainLoop

    cfg = dataclasses.replace(smoke_config("qwen2.5-14b"), num_layers=2)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    loop = TrainLoop(cfg, dcfg, str(tmp_path / "ck"), ckpt_every=3)
    h1 = loop.run(num_steps=4, log_every=100, log=lambda *_: None)
    assert len(h1) == 4 and all(np.isfinite(m["loss"]) for m in h1)

    # simulate restart: a new loop resumes from step 3's checkpoint
    loop2 = TrainLoop(cfg, dcfg, str(tmp_path / "ck"), ckpt_every=3)
    assert loop2.start_step == 3
    h2 = loop2.run(num_steps=2, log_every=100, log=lambda *_: None)
    assert [m["step"] for m in h2] == [3, 4]


def test_watchdog_flags_stragglers():
    from repro.train.loop import StepWatchdog

    wd = StepWatchdog(deadline_factor=2.0)
    for _ in range(10):
        assert not wd.observe(0.1)
    assert wd.observe(0.5)
    assert wd.straggler_steps == 1
