"""Async scheduler correctness (the PR-5 acceptance contract).

The load-bearing invariant: greedy token streams produced by the
event-driven scheduler are BIT-IDENTICAL to ``ServeEngine.run()`` on the
same request set — per runtime backend (``ref`` / ``pallas`` / quiet
``acim``) and on a 1x1 mesh — because the scheduler drives exactly the
engine's compiled prefill/decode internals and ``run()`` is a thin driver
over the scheduler.  On top of that: streaming callbacks must replay the
final outputs token for token, seeded sampling must reproduce, and the
admission-policy edges (bounded queue, deadline expiry, pool-full _admit)
must fail loudly instead of silently.
"""

import dataclasses

import jax
import pytest

from repro import runtime
from repro.configs.registry import smoke_config
from repro.models.model import init_params
from repro.runtime.executor import ACIMExecutor
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import (
    ManualClock,
    QueueFull,
    SamplingParams,
    Scheduler,
    sample_token,
)

# a zero-noise acim executor: must trace the exact same program as "pallas",
# so its greedy serving streams are part of the bit-identity acceptance
runtime.register_executor(
    "acim-quiet", ACIMExecutor(cim=runtime.quiet_cim_config())
)


@pytest.fixture(scope="module")
def float_setup():
    cfg = smoke_config("qwen2.5-14b")
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def kan_setup():
    cfg = smoke_config("qwen2.5-14b").kan_variant()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def make_reqs(cfg, n=2, plen=5, max_new=3, seed=42, **kw):
    rng = jax.random.PRNGKey(seed)
    reqs = []
    for rid in range(n):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (plen,), 3, cfg.vocab_size).tolist()
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                            **kw))
    return reqs


def scheduler_streams(engine, reqs):
    """Run reqs through an explicit Scheduler, collecting streamed tokens."""
    sched = Scheduler(engine)
    streams = {}
    for r in reqs:
        sched.submit(
            r, on_token=lambda req, t: streams.setdefault(req.rid, []).append(t)
        )
    finished = sched.run_until_idle()
    return {r.rid: r.output for r in finished}, streams, sched


@pytest.mark.parametrize("backend", ["ref", "pallas", "acim-quiet"])
def test_scheduler_greedy_stream_bit_identical_to_run(kan_setup, backend):
    """Acceptance: scheduler == run() token streams per backend, and the
    on_token stream replays the final outputs exactly."""
    cfg, params = kan_setup
    eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                      kan_backend=backend)
    ref_out = {r.rid: r.output for r in eng.run(make_reqs(cfg))}

    eng2 = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                       kan_backend=backend)
    out, streams, sched = scheduler_streams(eng2, make_reqs(cfg))
    assert out == ref_out
    assert streams == ref_out
    s = sched.stats()
    assert s["completed"] == len(ref_out) and s["expired"] == 0


def test_scheduler_greedy_mesh_1x1_matches_unmeshed_run(kan_setup):
    """A 1x1 mesh serves the same tokens as no mesh at all, through the
    scheduler (shard_map wrapping must stay bit-invisible)."""
    from repro.launch.mesh import make_local_mesh

    cfg, params = kan_setup
    eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True)
    ref_out = {r.rid: r.output for r in eng.run(make_reqs(cfg))}

    mesh = make_local_mesh(1, 1)
    eng2 = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                       mesh=mesh)
    out, streams, _ = scheduler_streams(eng2, make_reqs(cfg))
    assert out == ref_out
    assert streams == ref_out


def test_seeded_sampling_reproducible_and_seed_sensitive(float_setup):
    cfg, params = float_setup
    sp = SamplingParams(temperature=0.9, top_k=16, top_p=0.95, seed=7)

    def serve(sampling):
        eng = ServeEngine(params, cfg, slots=2, max_len=32)
        out, _, _ = scheduler_streams(
            eng, make_reqs(cfg, n=3, max_new=4, sampling=sampling)
        )
        return out

    a, b = serve(sp), serve(sp)
    assert a == b  # same seed -> byte-identical streams
    c = serve(dataclasses.replace(sp, seed=8))
    assert c != a  # a different seed draws a different stream
    greedy = serve(None)
    assert a != greedy  # temperature actually samples


def test_sampling_top_k_one_collapses_to_greedy(float_setup):
    """top_k=1 keeps only the argmax token: any temperature must emit the
    greedy stream (sampling reduces to selection, bit-identical)."""
    cfg, params = float_setup
    eng = ServeEngine(params, cfg, slots=2, max_len=32)
    greedy = {r.rid: r.output for r in eng.run(make_reqs(cfg, n=2))}
    eng2 = ServeEngine(params, cfg, slots=2, max_len=32)
    out, _, _ = scheduler_streams(
        eng2,
        make_reqs(cfg, n=2, sampling=SamplingParams(temperature=3.0, top_k=1)),
    )
    assert out == greedy


def test_sample_token_validates_params():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-2)
    # pure function: same (logits, params, rid, pos) -> same token
    import numpy as np

    logits = np.linspace(-1.0, 1.0, 32).astype(np.float32)
    sp = SamplingParams(temperature=1.0, top_p=0.9, seed=3)
    assert sample_token(logits, sp, 5, 2) == sample_token(logits, sp, 5, 2)


def test_queue_full_admission_rejected(float_setup):
    cfg, params = float_setup
    eng = ServeEngine(params, cfg, slots=1, max_len=32)
    sched = Scheduler(eng, max_queue=1)
    r0, r1 = make_reqs(cfg, n=2)
    sched.submit(r0)
    with pytest.raises(QueueFull):
        sched.submit(r1)
    assert sched.stats()["rejected"] == 1
    sched.run_until_idle()  # the admitted request still completes
    assert r0.status == "done" and len(r0.output) == r0.max_new_tokens


def test_deadline_expiry_while_queued(float_setup):
    """With one slot busy, a queued request whose deadline lapses is expired
    unserved: empty output, status 'expired', on_done fired, counted."""
    cfg, params = float_setup
    clock = ManualClock()
    eng = ServeEngine(params, cfg, slots=1, max_len=32)
    sched = Scheduler(eng, clock=clock)
    r0, r1 = make_reqs(cfg, n=2, max_new=6)
    r1.deadline_s = 0.5
    done_order = []
    sched.submit(r0, on_done=lambda r: done_order.append(r.rid))
    sched.submit(r1, on_done=lambda r: done_order.append(r.rid))
    sched.step()            # admits r0; r1 queued behind the single slot
    clock.advance(1.0)      # r1's queued wait now exceeds its deadline
    sched.step()
    assert r1.status == "expired" and r1.done and r1.output == []
    assert done_order == [1]
    sched.run_until_idle()
    assert r0.status == "done" and len(r0.output) == 6
    s = sched.stats()
    assert s["expired"] == 1 and s["completed"] == 1
    assert done_order == [1, 0]


def test_future_arrivals_wait_and_stats_snapshot(float_setup):
    """A request with a future arrival_s stays invisible to admission until
    its offset; run_until_idle advances a ManualClock across the gap."""
    cfg, params = float_setup
    clock = ManualClock()
    eng = ServeEngine(params, cfg, slots=2, max_len=32)
    sched = Scheduler(eng, clock=clock)
    r0, r1 = make_reqs(cfg, n=2)
    r1.arrival_s = 5.0
    sched.submit(r0)
    sched.submit(r1)
    sched.run_until_idle()
    assert r0.status == "done" and r1.status == "done"
    assert sched.elapsed() >= 5.0          # the loop waited for the arrival
    assert r1.ttft_s <= 0.5                # TTFT from arrival, not submit
    s = sched.stats()
    assert s["submitted"] == 2 and s["completed"] == 2
    assert s["tokens"] == len(r0.output) + len(r1.output)
    assert s["ttft_s"]["n"] == 2 and s["ttft_s"]["p95"] is not None
    assert s["queue_depth"]["samples"] > 0
    assert len(sched.queue_depth_trace()) == s["queue_depth"]["samples"]


def test_engine_admit_without_free_slot_raises(float_setup):
    cfg, params = float_setup
    eng = ServeEngine(params, cfg, slots=1, max_len=32)
    r0, r1 = make_reqs(cfg, n=2)
    eng._admit(r0)
    with pytest.raises(RuntimeError, match="free slot"):
        eng._admit(r1)


def test_scheduler_adopts_slots_admitted_directly_on_engine(float_setup):
    """A request admitted via ServeEngine._admit (direct engine use) before
    the scheduler takes over must be adopted, not crash the decode round:
    run() drains it alongside scheduler-admitted requests."""
    cfg, params = float_setup
    eng = ServeEngine(params, cfg, slots=2, max_len=32)
    r0, r1 = make_reqs(cfg, n=2, max_new=4)
    eng._admit(r0)                # behind the scheduler's back
    results = eng.run([r1])       # run() wraps a fresh Scheduler
    assert {r.rid for r in results} == {0, 1}
    assert r0.status == "done" and len(r0.output) == 4
    assert r1.status == "done" and len(r1.output) == 4


def test_long_lived_scheduler_memory_stays_bounded(float_setup):
    """Memory-bounds regression for a long-lived scheduler: hundreds of
    requests through ONE scheduler (paged engine, chunked prefill) must
    leave only capped/scalar state behind — stat tails capped at 4096,
    per-request records bounded by the slot count, callback maps emptied
    on retire, finished drained by the caller, and the KV pool back to
    empty with a consistent free/ref/evictable partition."""
    cfg, params = float_setup
    eng = ServeEngine(params, cfg, slots=2, max_len=32,
                      kv_block_size=8, prefill_chunk=4)
    sched = Scheduler(eng)
    total, wave = 520, 65
    for start in range(0, total, wave):
        for rid in range(start, start + wave):
            # fixed-shape prompts: one prefill trace, the loop stays fast
            sched.submit(Request(rid=rid, prompt=[3 + rid % 29] * 5,
                                 max_new_tokens=1),
                         on_token=lambda r, t: None,
                         on_done=lambda r: None)
        sched.run_until_idle()
        drained = sched.drain_finished()
        assert len(drained) == wave and not sched.finished
        # per-request state lives only while a request is active
        assert len(sched._rec) <= eng.slots
        assert not sched._on_token and not sched._on_done
    s = sched.stats()
    assert s["submitted"] == total and s["completed"] == total
    # prefill token + the decode round that observes len >= max_new
    assert s["tokens"] == 2 * total
    # stat tails are capped deques — a long-lived scheduler's footprint
    # does not grow with total requests served
    for tail in (sched._ttfts, sched._itls, sched._depth_samples):
        assert tail.maxlen == 4096 and len(tail) <= 4096
    assert s["ttft_s"]["n"] == min(total, 4096)
    assert s["queue_depth"]["rounds"] >= s["queue_depth"]["samples"]
    # the paged pool drained clean: no leaked blocks, invariants hold
    assert eng.pool.blocks_in_use() == 0
    assert eng._free_slots == list(range(eng.slots))
    eng.pool.check_consistent()


def test_stats_snapshot_safe_with_zero_requests(float_setup):
    """stats() on a fresh scheduler: every field defined, no div-zero, no
    NaN anywhere (the snapshot must stay strict-JSON serializable)."""
    import json

    cfg, params = float_setup
    sched = Scheduler(ServeEngine(params, cfg, slots=2, max_len=32))
    s = sched.stats()
    assert s["submitted"] == 0 and s["completed"] == 0
    assert s["tokens"] == 0 and s["tokens_per_s"] is None
    assert s["ttft_s"] is None
    assert s["itl_s"] == {"n": 0, "mean": None, "p50": None, "p95": None}
    assert s["queue_depth"]["mean"] == 0.0
    json.dumps(s, allow_nan=False)  # raises on any NaN/Inf


def test_stats_expired_only_workload_reports_null_ttft(float_setup):
    """Every request expires in the queue (no first token ever): ttft_s is
    None — not an empty summary, not garbage — and nothing divides by
    zero."""
    import json

    cfg, params = float_setup
    clock = ManualClock()
    sched = Scheduler(ServeEngine(params, cfg, slots=1, max_len=32),
                      clock=clock)
    for r in make_reqs(cfg, n=2):
        r.deadline_s = 0.5
        sched.submit(r)
    clock.advance(1.0)      # both deadlines lapse before any admission
    sched.step()
    s = sched.stats()
    assert s["expired"] == 2 and s["completed"] == 0
    assert s["ttft_s"] is None and s["tokens_per_s"] is None
    assert s["tokens"] == 0
    json.dumps(s, allow_nan=False)


def test_request_defaults_keep_old_call_sites_working():
    """Pre-scheduler construction (rid/prompt/max_new_tokens only) must keep
    working: arrival 'now', no deadline, greedy."""
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4)
    assert r.arrival_s == 0.0 and r.deadline_s is None and r.sampling is None
    assert r.status == "pending" and r.ttft_s == 0.0
