"""Unit + property tests for the uniform B-spline reference."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bspline import bspline_basis, cardinal_bump, num_basis


@pytest.mark.parametrize("order", [1, 2, 3, 4])
@pytest.mark.parametrize("grid", [1, 3, 5, 16])
def test_partition_of_unity(order, grid):
    x = jnp.linspace(0.0, 1.0, 257)
    b = bspline_basis(x, 0.0, 1.0, grid, order)
    assert b.shape == (257, grid + order)
    np.testing.assert_allclose(np.asarray(b.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(b) >= -1e-6).all()


@pytest.mark.parametrize("order", [2, 3, 4])
def test_shifted_copies_of_cardinal_bump(order):
    """Uniform knots => B_i(x) = b_K(x/h - i + K): the ASP-shareability fact."""
    g, lo, hi = 7, -2.0, 3.0
    h = (hi - lo) / g
    x = np.linspace(lo, hi - 1e-6, 301)
    b = np.asarray(bspline_basis(jnp.asarray(x, jnp.float32), lo, hi, g, order))
    for i in range(num_basis(g, order)):
        expect = cardinal_bump((x - lo) / h - i + order, order)
        np.testing.assert_allclose(b[:, i], expect, atol=2e-5)


@pytest.mark.parametrize("order", [1, 2, 3, 4, 5])
def test_cardinal_bump_symmetry_and_support(order):
    t = np.linspace(-1.0, order + 2.0, 501)
    v = cardinal_bump(t, order)
    np.testing.assert_allclose(v, cardinal_bump(order + 1 - t, order), atol=1e-12)
    assert (v[(t < 0) | (t > order + 1)] == 0).all()
    # integrates to 1 (B-splines are densities)
    tt = np.linspace(0, order + 1, 20001)
    assert abs(np.trapezoid(cardinal_bump(tt, order), tt) - 1.0) < 1e-6


@settings(max_examples=25, deadline=None)
@given(
    grid=st.integers(1, 32),
    order=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_pou_and_local_support(grid, order, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 1, size=64), jnp.float32)
    b = np.asarray(bspline_basis(x, -1.0, 1.0, grid, order))
    np.testing.assert_allclose(b.sum(-1), 1.0, atol=1e-4)
    # at most order+1 non-zero bases anywhere (local support)
    assert (np.count_nonzero(b > 1e-7, axis=-1) <= order + 1).all()
