"""Per-architecture smoke tests: reduced config, forward + one train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, smoke_config
from repro.models.model import forward, init_params, loss_fn
from repro.train.optimizer import adamw, apply_updates

# top-3 slowest tier-1 suite: kept in CI, deselectable locally
pytestmark = pytest.mark.slow


def _batch(cfg, key, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.num_patches, cfg.patch_embed_dim)
        )
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_and_finite(name):
    cfg = smoke_config(name)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    logits = forward(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_one_train_step_no_nans(name):
    cfg = smoke_config(name)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    opt = adamw(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    params, opt_state, loss = step(params, opt_state)
    assert bool(jnp.isfinite(loss)), name
    flat = jax.tree.leaves(params)
    assert all(bool(jnp.isfinite(x).all()) for x in flat), name


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_loss_decreases_three_steps(name):
    """Three steps on one batch must reduce loss (substrate actually learns)."""
    cfg = smoke_config(name)
    if cfg.num_experts:  # avoid capacity-drop nondeterminism in this check
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    opt = adamw(3e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    losses = []
    for _ in range(3):
        params, opt_state, loss = step(params, opt_state)
        losses.append(float(loss))
    assert losses[-1] < losses[0], (name, losses)
