"""Observability layer acceptance (the PR-8 contract).

Three load-bearing invariants:

  * **bit-identity** — greedy token streams are unchanged by observability:
    recording (metrics + tracing) never feeds back into execution;
  * **determinism** — an identical workload under ``ManualClock`` exports a
    byte-identical JSONL trace run to run, and ref/pallas backends produce
    the same span skeleton (ids, parents, timestamps — attrs may differ);
  * **zero-cost when off** — with the registry disabled (the default),
    instrument record calls are no-ops and leave no series behind.

Plus the mechanics: registry semantics (get-or-create, kind mismatch,
labels, collectors, reset), Prometheus text round-trip through the strict
parser, the /metrics HTTP server, structured-logger level filtering and
the bare-lambda back-compat path.
"""

import json
import urllib.request

import jax
import pytest

from repro import obs
from repro.configs.registry import smoke_config
from repro.models.model import init_params
from repro.obs.metrics import MetricsRegistry
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import ManualClock, Scheduler


@pytest.fixture(scope="module")
def kan_setup():
    cfg = smoke_config("qwen2.5-14b").kan_variant()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def float_setup():
    cfg = smoke_config("qwen2.5-14b")
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture
def obs_on():
    """Enable recording for one test; leave the process as it was found."""
    obs.REGISTRY.reset()
    obs.enable()
    yield
    obs.disable()
    obs.REGISTRY.reset()


def make_reqs(cfg, n=2, plen=5, max_new=3, seed=42, **kw):
    rng = jax.random.PRNGKey(seed)
    reqs = []
    for rid in range(n):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (plen,), 3, cfg.vocab_size).tolist()
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                            **kw))
    return reqs


# -- metrics registry ---------------------------------------------------------


def test_registry_instruments_and_labels(obs_on):
    r = MetricsRegistry()
    r.counter("c").inc()
    r.counter("c").inc(2)
    r.counter("d").inc(backend="pallas")
    r.gauge("g").set(7.5)
    r.histogram("h", edges=(1.0, 2.0, 4.0)).observe(1.5)
    snap = r.snapshot()["metrics"]
    assert snap["c"] == {"kind": "counter", "value": 3}
    assert snap["d{backend=pallas}"]["value"] == 1
    assert snap["g"]["value"] == 7.5
    h = snap["h"]["value"]
    # fixed edges, value 1.5 lands in the (1, 2] bucket
    assert h["edges"] == [1.0, 2.0, 4.0]
    assert h["counts"] == [0, 1, 0, 0] and h["count"] == 1 and h["sum"] == 1.5
    # get-or-create: same name -> same instrument; kind mismatch refuses
    assert r.counter("c") is r.counter("c")
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("c")


def test_disabled_recording_is_a_noop():
    obs.disable()
    r = MetricsRegistry()
    r.counter("c").inc()
    r.gauge("g").set(1.0)
    r.histogram("h").observe(0.5)
    assert r.snapshot()["metrics"] == {}
    # a bound label view is equally inert
    r.counter("c").labels(backend="ref").inc()
    assert r.snapshot()["metrics"] == {}


def test_histogram_rejects_unsorted_edges(obs_on):
    with pytest.raises(ValueError, match="strictly increase"):
        MetricsRegistry().histogram("h", edges=(2.0, 1.0))


def test_collectors_feed_snapshots_and_survive_reset(obs_on):
    r = MetricsRegistry()
    fn = lambda: {"pool.depth": 4,
                  ("disp.count", (("backend", "ref"),)): 9}
    r.register_collector(fn)
    snap = r.snapshot()["metrics"]
    assert snap["pool.depth"] == {"kind": "gauge", "value": 4}
    assert snap["disp.count{backend=ref}"]["value"] == 9
    r.reset()  # collectors survive a plain reset (import-time registrations)
    assert r.snapshot()["metrics"]["pool.depth"]["value"] == 4
    r.unregister_collector(fn)
    assert r.snapshot()["metrics"] == {}


def test_plan_cache_collector_registered_on_global_registry(obs_on):
    snap = obs.REGISTRY.snapshot()["metrics"]
    for k in ("plan_cache.hits", "plan_cache.misses", "plan_cache.traces"):
        assert k in snap and snap[k]["kind"] == "gauge"


# -- exposition ---------------------------------------------------------------


def test_prometheus_text_round_trips_strict_parser(obs_on):
    r = MetricsRegistry()
    r.counter("serve.tokens").inc(12)
    r.counter("runtime.backend_dispatch").inc(3, backend="pallas")
    r.histogram("serve.ttft_s", edges=(0.1, 1.0)).observe(0.05)
    r.histogram("serve.ttft_s", edges=(0.1, 1.0)).observe(5.0)
    text = obs.prometheus_text(r)
    parsed = obs.parse_prometheus_text(text)
    assert parsed["serve_tokens"] == 12
    assert parsed['runtime_backend_dispatch{backend="pallas"}'] == 3
    # cumulative buckets: 0.05 <= 0.1; 5.0 overflows to +Inf only
    assert parsed['serve_ttft_s_bucket{le="0.1"}'] == 1
    assert parsed['serve_ttft_s_bucket{le="1"}'] == 1
    assert parsed['serve_ttft_s_bucket{le="+Inf"}'] == 2
    assert parsed["serve_ttft_s_count"] == 2
    assert parsed["serve_ttft_s_sum"] == pytest.approx(5.05)
    with pytest.raises(ValueError, match="not a valid prometheus sample"):
        obs.parse_prometheus_text("this is { not a sample\n")


def test_dump_metrics_json_and_prom(tmp_path, obs_on):
    obs.REGISTRY.counter("serve.tokens").inc(5)
    pj, pp = tmp_path / "m.json", tmp_path / "m.prom"
    obs.dump_metrics(pj)
    obs.dump_metrics(pp)
    assert json.loads(pj.read_text())["metrics"]["serve.tokens"]["value"] == 5
    assert obs.parse_prometheus_text(pp.read_text())["serve_tokens"] == 5


def test_metrics_http_server_serves_both_formats(obs_on):
    obs.REGISTRY.counter("serve.tokens").inc(7)
    srv = obs.start_metrics_server(0)  # ephemeral port
    try:
        base = f"http://127.0.0.1:{srv.server_port}"
        text = urllib.request.urlopen(f"{base}/metrics").read().decode()
        assert obs.parse_prometheus_text(text)["serve_tokens"] == 7
        snap = json.loads(
            urllib.request.urlopen(f"{base}/metrics.json").read())
        assert snap["metrics"]["serve.tokens"]["value"] == 7
    finally:
        srv.shutdown()


# -- structured logging -------------------------------------------------------


def test_logger_level_filtering_and_formatting(monkeypatch):
    lines = []
    lg = obs.Logger("sched", sink=lines.append, level="info")
    lg.debug("dropped", rid=1)
    lg.info("request done", rid=3, latency_s=0.0421)
    lg.warning("backpressure", queued=4)
    assert lines == [
        "sched: request done rid=3 latency_s=0.0421",
        "sched: [warning] backpressure queued=4",
    ]
    # level=None re-reads REPRO_LOG_LEVEL per record
    lines.clear()
    envlg = obs.Logger("s", sink=lines.append)
    monkeypatch.setenv(obs.ENV_LOG_LEVEL_VAR, "error")
    envlg.info("hidden")
    monkeypatch.setenv(obs.ENV_LOG_LEVEL_VAR, "debug")
    envlg.debug("shown")
    assert lines == ["s: [debug] shown"]


def test_as_logger_back_compat_paths():
    # bare callable: DEBUG threshold, every record forwarded (legacy log=)
    got = []
    lg = obs.as_logger(got.append)
    lg.debug("admitted request", rid=0)
    lg("request done", rid=0)        # __call__ keeps the old lambda shape
    assert got == ["[debug] admitted request rid=0", "request done rid=0"]
    # None -> the named process logger; Logger -> itself
    assert obs.as_logger(None, "x") is obs.get_logger("x")
    assert obs.as_logger(lg) is lg
    with pytest.raises(TypeError):
        obs.as_logger(42)


# -- tracer -------------------------------------------------------------------


def test_tracer_records_events_spans_and_trims():
    clk = ManualClock()
    tr = obs.Tracer(clock=clk.now, max_records=3)
    root = tr.begin("request", rid=5)
    clk.advance(1.0)
    tr.event("first_token", parent=root)
    child = tr.begin("decode", parent=root)
    clk.advance(0.5)
    tr.end(child, tokens=2)
    tr.end(root, status="done")
    with pytest.raises(ValueError, match="already ended"):
        tr.end(root)
    recs = tr.records()
    assert len(recs) == 3 and tr.dropped == 0
    assert [r["id"] for r in recs] == [0, 1, 2]  # sequence-number ids
    ev = next(r for r in recs if r["type"] == "event")
    assert ev["rid"] == 5 and ev["t0"] == 1.0  # rid inherits from parent
    # past the cap the oldest CLOSED record is dropped; export notes it
    tr.event("extra")
    assert tr.dropped == 1
    assert [r["name"] for r in tr.records()] == [
        "first_token", "decode", "extra"]


def _serve_traced(params, cfg, backend, path):
    """One deterministic 2-request workload (one future arrival) under
    ManualClock, traced; exports JSONL to ``path`` and returns outputs."""
    clock = ManualClock()
    eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                      kan_backend=backend)
    sched = Scheduler(eng, clock=clock, trace=True)
    reqs = make_reqs(cfg, n=2, max_new=3)
    reqs[1].arrival_s = 2.5
    for r in reqs:
        sched.submit(r)
    sched.run_until_idle()
    sched.tracer.export_jsonl(path)
    return {r.rid: r.output for r in sched.finished}, sched


def test_trace_jsonl_byte_identical_across_runs(kan_setup, tmp_path):
    cfg, params = kan_setup
    out1, _ = _serve_traced(params, cfg, "pallas", tmp_path / "a.jsonl")
    out2, _ = _serve_traced(params, cfg, "pallas", tmp_path / "b.jsonl")
    assert out1 == out2
    a, b = (tmp_path / "a.jsonl").read_bytes(), \
        (tmp_path / "b.jsonl").read_bytes()
    assert a == b and a  # identical and non-empty


def test_trace_skeleton_identical_across_backends(kan_setup, tmp_path):
    """ref and pallas serve the same schedule -> same span tree (ids,
    parents, rids, ManualClock timestamps); attrs are allowed to differ."""
    cfg, params = kan_setup
    out_r, sch_r = _serve_traced(params, cfg, "ref", tmp_path / "r.jsonl")
    out_p, sch_p = _serve_traced(params, cfg, "pallas", tmp_path / "p.jsonl")
    assert out_r == out_p                  # greedy bit-identity, per PR-5
    sk_r, sk_p = sch_r.tracer.skeleton(), sch_p.tracer.skeleton()
    assert sk_r == sk_p and len(sk_r) > 0


def test_trace_span_taxonomy_complete_timeline(float_setup):
    """A served request leaves the full documented span tree: request >
    queued/prefill/decode spans (all closed) + a first_token event."""
    cfg, params = float_setup
    clock = ManualClock()
    eng = ServeEngine(params, cfg, slots=2, max_len=32)
    sched = Scheduler(eng, clock=clock, trace=True)
    for r in make_reqs(cfg, n=1, max_new=3):
        sched.submit(r)
    sched.run_until_idle()
    recs = sched.tracer.records()
    by_name = {}
    for r in recs:
        by_name.setdefault(r["name"], []).append(r)
    for name in ("request", "queued", "prefill", "decode"):
        (span,) = by_name[name]
        assert span["type"] == "span" and span["t1"] is not None
        assert span["rid"] == 0
    (ft,) = by_name["first_token"]
    assert ft["type"] == "event" and ft["parent"] == by_name["request"][0]["id"]
    assert by_name["request"][0]["attrs"]["status"] == "done"
    assert by_name["decode"][0]["attrs"]["tokens"] == 3
    # expired-while-queued requests close their tree too
    sched2 = Scheduler(ServeEngine(params, cfg, slots=1, max_len=32),
                       clock=clock, trace=True)
    (rq,) = make_reqs(cfg, n=1)
    rq.deadline_s = 0.5
    sched2.submit(rq)
    clock.advance(1.0)
    sched2.step()
    (root,) = [r for r in sched2.tracer.records() if r["name"] == "request"]
    assert root["attrs"]["status"] == "expired" and root["t1"] is not None


def test_chrome_export_shape(float_setup, tmp_path):
    cfg, params = float_setup
    clock = ManualClock()
    eng = ServeEngine(params, cfg, slots=2, max_len=32)
    sched = Scheduler(eng, clock=clock, trace=True)
    for r in make_reqs(cfg, n=1, max_new=2):
        sched.submit(r)
    sched.run_until_idle()
    path = tmp_path / "t.json"
    sched.tracer.export_chrome(path)
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} <= {"X", "i"}
    assert all(e["tid"] == 0 for e in evs)  # one timeline row per request


# -- end-to-end: bit-identity + metrics coverage ------------------------------


def test_greedy_streams_bit_identical_with_obs_enabled(float_setup):
    """The headline acceptance: observability on (metrics + tracing) must
    not change a single emitted token."""
    cfg, params = float_setup
    eng = ServeEngine(params, cfg, slots=2, max_len=32)
    baseline = {r.rid: r.output for r in eng.run(make_reqs(cfg))}
    obs.enable()
    try:
        eng2 = ServeEngine(params, cfg, slots=2, max_len=32)
        sched = Scheduler(eng2, trace=True)
        for r in make_reqs(cfg):
            sched.submit(r)
        sched.run_until_idle()
        assert {r.rid: r.output for r in sched.finished} == baseline
    finally:
        obs.disable()
        obs.REGISTRY.reset()


def test_served_workload_covers_documented_metric_names(kan_setup, obs_on):
    """A served request on a paged KAN engine populates the documented
    dotted names across all three subsystems (the acceptance snapshot)."""
    cfg, params = kan_setup
    eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                      kan_backend="ref", kv_block_size=8)
    sched = Scheduler(eng)
    for r in make_reqs(cfg, n=2, max_new=2):
        sched.submit(r)
    sched.run_until_idle()
    snap = obs.REGISTRY.snapshot()["metrics"]
    assert snap["serve.submitted"]["value"] == 2
    assert snap["serve.completed"]["value"] == 2
    assert snap["serve.tokens"]["value"] == sched.stats()["tokens"]
    assert snap["serve.ttft_s"]["kind"] == "histogram"
    assert snap["serve.ttft_s"]["value"]["count"] == 2
    assert "kv.blocks_in_use" in snap and "kv.prefix_hits" in snap
    assert "plan_cache.hits" in snap
    assert snap["runtime.backend_dispatch{backend=ref}"]["value"] > 0
    # and the whole snapshot survives strict Prometheus exposition
    obs.parse_prometheus_text(obs.prometheus_text())
