"""Serving-path correctness: prefill + decode must agree with full forward.

This is the invariant the decode_32k / long_500k dry-run cells rely on: the
rolling-window KV cache, recurrent states, and SSD states all reproduce the
full-sequence computation token by token.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import smoke_config
from repro.models.model import decode_step, forward, init_params, prefill

# top-3 slowest tier-1 suite: kept in CI, deselectable locally
pytestmark = pytest.mark.slow

ARCHS_TO_CHECK = [
    "llama3-405b", "qwen2.5-14b", "gemma2-27b", "mixtral-8x7b",
    "recurrentgemma-9b", "mamba2-370m", "whisper-base", "pixtral-12b",
    "olmoe-1b-7b",
]


def _batch(cfg, key, b, s):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.num_patches, cfg.patch_embed_dim)
        )
    return batch


@pytest.mark.parametrize("name", ARCHS_TO_CHECK)
def test_prefill_then_decode_matches_forward(name):
    cfg = smoke_config(name)
    if cfg.num_experts:  # disable capacity drops for the equivalence check
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    b, s = 2, 24
    batch = _batch(cfg, key, b, s)
    full = forward(params, batch, cfg)

    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, : s - 3]
    logits_p, cache = prefill(params, pb, cfg, max_len=s + 8)
    scale = float(jnp.abs(full).max())
    assert float(jnp.abs(logits_p - full[:, s - 4]).max()) < 2e-3 * scale + 1e-4

    npfx = cfg.num_patches if cfg.family == "vlm" else 0
    for i in range(s - 3, s):  # decode the last 3 tokens
        pos = jnp.full((b,), i + npfx, jnp.int32)
        logits_d, cache = decode_step(params, cache, batch["tokens"][:, i], pos, cfg)
        err = float(jnp.abs(logits_d - full[:, i]).max())
        assert err < 2e-3 * scale + 1e-4, (name, i, err)


def test_serve_engine_kan_ffn_fused_path_matches_float_tokens():
    """--kan-ffn serving regression: a small greedy batch decodes the SAME
    tokens whether the KAN-FFN blocks run on the float path or are
    ASP-quantized and executed through the fused Pallas pipeline
    (kan_deploy=True, interpret mode on CPU).  int8 + SH-LUT error is far
    below the greedy argmax margin on this config."""
    from repro.serve.engine import Request, ServeEngine

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)

    def make_reqs():
        rng = jax.random.PRNGKey(42)
        reqs = []
        for rid in range(3):
            rng, k = jax.random.split(rng)
            prompt = jax.random.randint(k, (6,), 3, cfg.vocab_size).tolist()
            reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=4))
        return reqs

    float_engine = ServeEngine(params, cfg, slots=2, max_len=32)
    float_out = {r.rid: r.output for r in float_engine.run(make_reqs())}

    fused_engine = ServeEngine(params, cfg, slots=2, max_len=32,
                               kan_deploy=True)
    fused_out = {r.rid: r.output for r in fused_engine.run(make_reqs())}

    assert fused_out == float_out


def test_serve_engine_kan_deploy_rejects_non_kan_config():
    from repro.serve.engine import ServeEngine

    cfg = smoke_config("qwen2.5-14b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True)


def test_serve_engine_rejects_unknown_kan_backend():
    from repro.serve.engine import ServeEngine

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                    kan_backend="tpu-magic")


def test_prefill_length_buckets_compile_once_per_bucket_same_tokens():
    """Prompt padding to power-of-two buckets: a mixed-length request stream
    compiles O(log L) prefill variants instead of one per distinct length,
    and (masked cache splice + true-last-token logits) decodes the SAME
    tokens as exact-length prefill."""
    from repro.serve.engine import Request, ServeEngine, \
        prefill_bucketing_supported

    cfg = smoke_config("qwen2.5-14b")
    assert prefill_bucketing_supported(cfg)  # pure global attention
    params = init_params(jax.random.PRNGKey(0), cfg)
    lengths = [3, 5, 6, 7, 9, 12]

    def make_reqs():
        rng = jax.random.PRNGKey(7)
        reqs = []
        for rid, ln in enumerate(lengths):
            rng, k = jax.random.split(rng)
            prompt = jax.random.randint(k, (ln,), 3, cfg.vocab_size).tolist()
            reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=3))
        return reqs

    bucketed = ServeEngine(params, cfg, slots=2, max_len=64)
    assert bucketed.prefill_buckets
    out_b = {r.rid: r.output for r in bucketed.run(make_reqs())}

    exact = ServeEngine(params, cfg, slots=2, max_len=64,
                        prefill_buckets=False)
    out_e = {r.rid: r.output for r in exact.run(make_reqs())}

    assert out_b == out_e
    # lengths {3,5,6,7} -> bucket 8; {9,12} -> bucket 16
    assert bucketed.prefill_traces == 2, bucketed.compile_stats()
    assert exact.prefill_traces == len(set(lengths))
    assert bucketed.decode_traces == 1


def test_prefill_bucketing_auto_disabled_for_stateful_stacks():
    """Recurrent/SSM/windowed stacks integrate pad tokens into their state —
    the engine must fall back to exact-length prefill for them."""
    from repro.serve.engine import prefill_bucketing_supported
    from repro.serve.engine import ServeEngine

    for name in ("mamba2-370m", "recurrentgemma-9b", "gemma2-27b"):
        cfg = smoke_config(name)
        assert not prefill_bucketing_supported(cfg), name
    cfg = smoke_config("mamba2-370m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, slots=2, max_len=32)
    assert not eng.prefill_buckets  # even though the default asks for it


def test_serve_engine_kan_backend_ref_matches_pallas_tokens():
    """kan_backend plumbs through repro.runtime: the layered "ref" executor
    and the fused "pallas" executor serve identical greedy tokens."""
    from repro.serve.engine import Request, ServeEngine

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    params = init_params(jax.random.PRNGKey(0), cfg)

    def make_reqs():
        rng = jax.random.PRNGKey(11)
        reqs = []
        for rid in range(2):
            rng, k = jax.random.split(rng)
            prompt = jax.random.randint(k, (5,), 3, cfg.vocab_size).tolist()
            reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=3))
        return reqs

    outs = {}
    for backend in ("ref", "pallas"):
        eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                          kan_backend=backend)
        outs[backend] = {r.rid: r.output for r in eng.run(make_reqs())}
    assert outs["ref"] == outs["pallas"]


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)
def test_serve_engine_mesh_sharded_same_tokens():
    """ServeEngine(mesh=...) — slot pool/KV on "data", KAN-FFN channels on
    "model" — must serve exactly the tokens of the single-device engine on
    the same request stream (the PR-4 acceptance criterion)."""
    from repro import runtime
    from repro.launch.mesh import make_local_mesh
    from repro.serve.engine import Request, ServeEngine

    cfg = smoke_config("qwen2.5-14b").kan_variant()
    params = init_params(jax.random.PRNGKey(0), cfg)

    def make_reqs():
        rng = jax.random.PRNGKey(21)
        reqs = []
        for rid in range(3):
            rng, k = jax.random.split(rng)
            prompt = jax.random.randint(k, (6,), 3, cfg.vocab_size).tolist()
            reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=3))
        return reqs

    runtime.reset_cache()
    e0 = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True)
    out0 = {r.rid: r.output for r in e0.run(make_reqs())}

    n = len(jax.devices())
    mesh = make_local_mesh(2, 2) if n >= 4 else make_local_mesh(2, 1)
    e1 = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                     mesh=mesh)
    out1 = {r.rid: r.output for r in e1.run(make_reqs())}
    assert out0 == out1
    layout = e1.compile_stats()["mesh"]
    assert layout["axes"] == ["data", "model"]
    assert layout["devices"] == layout["shape"][0] * layout["shape"][1]
    assert e0.compile_stats()["mesh"] is None


def test_rolling_window_cache_exceeding_window():
    """Decode past the window: rolling cache must equal full SWA attention."""
    cfg = smoke_config("mixtral-8x7b")
    cfg = dataclasses.replace(cfg, window_size=8, moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    b, s = 1, 20  # s >> window
    batch = _batch(cfg, key, b, s)
    full = forward(params, batch, cfg)
    pb = {"tokens": batch["tokens"][:, :4]}
    _, cache = prefill(params, pb, cfg, max_len=s)
    scale = float(jnp.abs(full).max())
    for i in range(4, s):
        pos = jnp.full((b,), i, jnp.int32)
        logits_d, cache = decode_step(params, cache, batch["tokens"][:, i], pos, cfg)
        err = float(jnp.abs(logits_d - full[:, i]).max())
        assert err < 2e-3 * scale + 1e-4, (i, err)
