"""Mixed-precision ASP quantization: properties + the golden-parity sweep.

Three layers of guarantees for the sub-8-bit (KANtize-style) deployment:

  * property-based invariants over bit widths 4..8 — PowerGap (eq. (6))
    acceptance is exact (``resolve_layer_bits`` accepts a width iff
    ``G * 2**LD <= 2**n`` is satisfiable, NEVER clamps, and names the
    offending layer), the ASP quantize->dequantize round-trip error is
    bounded by one code step and pointwise monotone in bits (the code
    grids are nested: +1 bit halves ``code_step`` at fixed G), and the
    int4 nibble codec round-trips signed codes exactly;
  * the packed banded MAC is bit-exact against an UNPACKED reference per
    layer: re-materializing any single packed layer's f32 banded matrix
    (via the kernel's own in-lane decode arithmetic) and re-running the
    fused pipeline must not move one bit of the output or any boundary
    code;
  * the golden-parity sweep: every (backend x mesh x bits) cell replays
    the conftest ``golden_parity`` bundles against the captured
    single-source-of-truth arrays — outputs and boundary codes bitwise.

``REPRO_TEST_BITS`` (CI matrix knob) restricts the sweep's bit cells:
``int8`` runs the uniform legacy allocation only, ``mixed48`` the
sub-8-bit allocations only; unset runs all of ``GOLDEN_BITS``.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import (
    GOLDEN_BACKENDS,
    GOLDEN_BITS,
    assert_bit_exact,
    ensure_quiet_acim_backend,
    kan1_bundle,
)
from repro.core.asp_quant import (
    ASPQuantSpec,
    dequantize_input,
    max_ld,
    quantize_input,
    resolve_layer_bits,
)
from repro.core.kan_network_deploy import kan_network_deploy_apply
from repro.kernels.kan_spline import pipeline as pl

ensure_quiet_acim_backend()

_N_DEV = len(jax.devices())


# ----------------------------------------------------------------------------
# PowerGap validity invariants (eq. (6)) — accept iff satisfiable, never clamp
# ----------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(g=st.integers(1, 40), b=st.integers(2, 16))
def test_powergap_accept_iff_satisfiable(g, b):
    """resolve_layer_bits accepts a width exactly when eq. (6) has a
    solution, and a valid width comes back verbatim (uniform broadcast)."""
    if max_ld(g, b) >= 0:
        assert resolve_layer_bits(b, 3, g) == (b, b, b)
    else:
        with pytest.raises(ValueError, match="PowerGap-invalid"):
            resolve_layer_bits(b, 3, g)


@settings(max_examples=24, deadline=None)
@given(
    b1=st.integers(4, 8),
    b2=st.integers(4, 8),
    g=st.sampled_from([3, 5, 7, 11, 16]),
)
def test_powergap_mixed_tuple_roundtrips_exactly(b1, b2, g):
    """A valid per-layer allocation is returned bit-for-bit — resolution is
    normalization, never adjustment."""
    assert resolve_layer_bits((b1, b2), 2, g) == (b1, b2)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(4, 8))
def test_powergap_invalid_layer_is_named_not_clamped(b):
    """G = 2**b + 1 cannot fit width b (G * 2**0 > 2**b): the error names
    the offending layer and no clamped tuple ever escapes."""
    g = 2**b + 1
    assert max_ld(g, 16) >= 0  # the 16-bit layer alone would be fine
    with pytest.raises(ValueError, match=f"layer 1: n_bits={b}"):
        resolve_layer_bits((16, b), 2, g)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 4), m=st.integers(1, 4))
def test_layer_count_mismatch_rejected(n, m):
    bits = (8,) * n
    if n == m:
        assert resolve_layer_bits(bits, m, 5) == bits
    else:
        with pytest.raises(ValueError, match="per-layer bit widths"):
            resolve_layer_bits(bits, m, 5)


# ----------------------------------------------------------------------------
# quantize -> dequantize round-trip error: bounded, monotone in bits
# ----------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), g=st.sampled_from([3, 5, 7]))
def test_roundtrip_error_bounded_and_monotone_in_bits(seed, g):
    """At fixed G every +1 bit halves code_step with the same origin, so
    the code grids are nested and the round-trip error is POINTWISE
    non-increasing in bits; the max error is bounded by one code step
    (half a step in the interior, up to a full step at the clipped hi
    edge)."""
    x = jax.random.uniform(
        jax.random.PRNGKey(seed), (256,), minval=-1.0, maxval=1.0
    )
    prev = None
    for b in range(4, 9):
        spec = ASPQuantSpec(grid_size=g, n_bits=b)
        x_rt = dequantize_input(quantize_input(x, spec), spec)
        err = float(jnp.max(jnp.abs(x_rt - x)))
        assert err <= spec.code_step + 1e-6, (b, err, spec.code_step)
        if prev is not None:
            assert err <= prev + 1e-7, (b, err, prev)
        prev = err


# ----------------------------------------------------------------------------
# int4 nibble codec + packed banded MAC vs unpacked reference, per layer
# ----------------------------------------------------------------------------


@settings(max_examples=16, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_nibble_codec_roundtrip_signed(seed):
    k = jax.random.PRNGKey(seed)
    lo = jax.random.randint(k, (37, 5), -8, 8, dtype=jnp.int32)
    hi = jax.random.randint(
        jax.random.fold_in(k, 1), (37, 5), -8, 8, dtype=jnp.int32
    )
    p = pl._pack_nibbles(lo, hi)
    assert p.dtype == jnp.int8
    p32 = p.astype(jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(pl._unpack_lo_nibble(p32)), np.asarray(lo)
    )
    np.testing.assert_array_equal(
        np.asarray(pl._unpack_hi_nibble(p32)), np.asarray(hi)
    )


def _unpack_layer(lw, lp):
    """Re-materialize a packed layer as the unpacked {"lut","wc","wb"} form
    the kernel's f32 branch consumes (the decode IS the kernel's in-lane
    arithmetic, so this is the unpacked reference deployment)."""
    return {"lut": lw["lut"], "wc": pl.unpacked_wc(lw, lp), "wb": lw["wb"]}


@pytest.mark.parametrize("bits", [(4, 4), (8, 4)], ids=str)
def test_packed_banded_mac_bit_exact_vs_unpacked_per_layer(bits):
    """Unpacking any single packed layer (and all of them) must be bitwise
    invisible — output AND every boundary code."""
    _, _, dep = kan1_bundle(n_bits=bits, batch=8)
    x = jax.random.uniform(
        jax.random.PRNGKey(5), (9, 17), minval=-1.0, maxval=1.0
    )
    want = kan_network_deploy_apply(
        dep, x, interpret=True, backend="pallas", return_intermediates=True
    )
    packed = [i for i, lw in enumerate(dep.layers) if "wcp" in lw]
    assert packed, "allocation deployed nothing packed"
    subsets = [[i] for i in packed] + ([packed] if len(packed) > 1 else [])
    for subset in subsets:
        layers = list(dep.layers)
        for i in subset:
            layers[i] = _unpack_layer(layers[i], dep.plan.layers[i])
        dep_u = dataclasses.replace(dep, layers=tuple(layers))
        got = kan_network_deploy_apply(
            dep_u, x, interpret=True, backend="pallas",
            return_intermediates=True,
        )
        assert_bit_exact(want, got)


def test_packed_deployment_shape_contract():
    """<=4-bit layers deploy {"wcp","wscale"} (half the contraction rows per
    int8 lane, no f32 "wc" at rest); 8-bit layers keep the unpacked form."""
    _, _, dep = kan1_bundle(n_bits=(8, 4), batch=8)
    l8, l4 = dep.layers
    lp8, lp4 = dep.plan.layers
    assert "wc" in l8 and "wcp" not in l8
    assert "wcp" in l4 and "wc" not in l4
    nb = lp4.spec.num_basis
    assert l4["wcp"].shape == (lp4.fp * nb // 2, lp4.op)
    assert l4["wcp"].dtype == jnp.int8
    assert l4["wscale"].shape == (1, lp4.op)
    assert tuple(pl.layer_weight_keys(lp4)) == tuple(sorted(
        l4.keys(), key=tuple(pl.layer_weight_keys(lp4)).index
    ))


# ----------------------------------------------------------------------------
# golden-parity sweep: every (backend, mesh, bits) cell vs the captured truth
# ----------------------------------------------------------------------------


def _bits_cells():
    sel = os.environ.get("REPRO_TEST_BITS", "")
    if sel == "int8":
        return tuple(b for b in GOLDEN_BITS if b == 8)
    if sel == "mixed48":
        return tuple(b for b in GOLDEN_BITS if b != 8)
    return GOLDEN_BITS


def _mesh(kind):
    from repro.launch.mesh import make_local_mesh

    if kind == "none":
        return None
    if kind == "1x1":
        return make_local_mesh(1, 1)
    return make_local_mesh(2, 1)  # data-only


@pytest.mark.parametrize("backend", GOLDEN_BACKENDS)
@pytest.mark.parametrize("mesh_kind", ["none", "1x1", "data2"])
@pytest.mark.parametrize("bits", _bits_cells(), ids=str)
def test_golden_parity_cell(golden_parity, backend, mesh_kind, bits):
    if mesh_kind == "data2" and _N_DEV < 2:
        pytest.skip(
            "needs >= 2 devices "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
    ent = golden_parity[bits]
    y, codes = kan_network_deploy_apply(
        ent["dep"], ent["x"], interpret=True, backend=backend,
        mesh=_mesh(mesh_kind), return_intermediates=True,
    )
    assert len(codes) == len(ent["codes"])
    for got, want in zip(codes, ent["codes"]):
        # the quantized datapath — boundary codes — is bitwise everywhere
        np.testing.assert_array_equal(np.asarray(got), want)
    if backend == "ref" and mesh_kind == "none":
        # the unsharded ref runs the LOGICAL un-padded composition, whose
        # f32 banded accumulation order differs from the kernel's padded
        # tiling by <= 1 ulp (the repo-wide ref output contract, cf.
        # test_runtime's allclose holds); meshed ref uses the padded
        # per-layer form and is bitwise like the rest.
        np.testing.assert_allclose(np.asarray(y), ent["y"],
                                   atol=1e-7, rtol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(y), ent["y"])
