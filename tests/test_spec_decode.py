"""Speculative decoding correctness (the PR-9 acceptance contract).

The load-bearing invariant: greedy token streams under ``spec_decode=k``
are BIT-IDENTICAL to plain decode (``spec_decode=0``) on the same request
set — for k in {2, 4}, per runtime backend (``ref`` / ``pallas`` / quiet
``acim``) and on a 1x1 mesh — because acceptance is the longest draft
prefix matching the target's own greedy argmax over verify rows that are
row-for-row bit-identical to sequential ``decode_step`` outputs.  The
drafter only decides how MANY of those rows are consumed per round; it can
never change WHICH token any position emits.

On top of that: ``verify_step`` row-level parity with ``decode_step``
(logits AND caches), the ``refit_layer_spec`` grid transfer at
simultaneously reduced G and K (deterministic replan, param-count shrink,
w_b passthrough), drafter deployment through the shared plan cache without
retracing any target entry, the KV pool's ``truncate`` rollback guards,
and the scheduler's spec metrics surface (``tokens_per_round``,
accept-rate block, per-emitted-token ITL counts).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ensure_quiet_acim_backend
from repro import runtime
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvpool import KVBlockPool
from repro.serve.scheduler import Scheduler
from repro.serve.spec import DraftModel, DraftSpec

# the tier-1 run's slowest suite: kept in CI, deselectable locally
pytestmark = pytest.mark.slow

# zero-noise acim executor (conftest harness): traces the same program as
# "pallas", so its greedy streams take part in the bit-identity acceptance;
# the shared session-scoped ``kan_setup`` fixture also lives in conftest
ensure_quiet_acim_backend()

PAGED = dict(kv_block_size=8, kv_blocks=32, prefill_chunk=8)


def make_reqs(cfg, n=3, max_new=6, seed=42):
    """Mixed-length prompts (different drafter prefill buckets + chunked
    engine prefill shapes) so rounds interleave prefills with spec rounds."""
    rng = jax.random.PRNGKey(seed)
    reqs = []
    for rid in range(n):
        rng, k = jax.random.split(rng)
        plen = 4 + 3 * rid
        prompt = jax.random.randint(k, (plen,), 3, cfg.vocab_size).tolist()
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
    return reqs


def serve(params, cfg, k, backend=None, mesh=None, draft_spec=None, reqs=None):
    eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                      kan_backend=backend, mesh=mesh, spec_decode=k,
                      draft_spec=draft_spec, **PAGED)
    out = {r.rid: r.output for r in eng.run(reqs or make_reqs(cfg))}
    return out, eng


# ---------------------------------------------------------------------------
# Acceptance: bit-identical greedy streams
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas", "acim-quiet"])
@pytest.mark.parametrize("k", [2, 4])
def test_spec_streams_bit_identical_per_backend(kan_setup, backend, k):
    cfg, params = kan_setup
    base, _ = serve(params, cfg, 0, backend=backend)
    out, eng = serve(params, cfg, k, backend=backend)
    assert out == base
    stats = eng.compile_stats()
    assert stats["verify_traces"] == 1  # one (slots, k+1) verify program
    assert stats["spec"]["k"] == k
    # the drafter deployed at the default halved grid on the same backend
    assert stats["spec"]["draft"]["kan_grid"] == max(2, cfg.kan_grid // 2)
    assert stats["spec"]["draft"]["kan_backend"] == backend


def test_spec_streams_bit_identical_with_draft_spec_and_k1(kan_setup):
    """A deliberately mismatched drafter (tiny grid, reduced order, fewer
    bits, different backend) and the degenerate k=1 round shape still
    reproduce the baseline stream exactly — acceptance, not drafting,
    decides every emitted token."""
    cfg, params = kan_setup
    base, _ = serve(params, cfg, 0)
    for k, spec in ((1, None), (2, "grid=2,order=2,bits=6,backend=ref")):
        out, _ = serve(params, cfg, k, draft_spec=spec)
        assert out == base, (k, spec)


def test_spec_mesh_1x1_bit_identical(kan_setup):
    from repro.launch.mesh import make_local_mesh

    cfg, params = kan_setup
    base, _ = serve(params, cfg, 0)
    out, _ = serve(params, cfg, 2, mesh=make_local_mesh(1, 1))
    assert out == base


# ---------------------------------------------------------------------------
# verify_step: row-for-row parity with sequential decode_step
# ---------------------------------------------------------------------------


def test_verify_step_rows_match_sequential_decode(kan_setup):
    """One batched (B, S) verify forward == S sequential decode_steps,
    bit-exact on logits AND on the KV written back to the paged pool."""
    cfg, params = kan_setup
    from repro.core.kan_ffn_deploy import quantize_kan_ffn_params_tree

    p = quantize_kan_ffn_params_tree(params, cfg)
    b, s, bs, nb = 2, 4, 8, 9
    table = jnp.asarray([[1, 2], [3, 4]], jnp.int32)  # 2 blocks/slot
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (b, s), 3, cfg.vocab_size)
    pos0 = jnp.asarray([0, 3], jnp.int32)  # unequal frontiers

    with runtime.use_backend("ref"):
        cache = M.init_paged_cache(p, cfg, nb, bs)
        seq = []
        for j in range(s):
            logits, cache = M.decode_step(p, cache, tokens[:, j], pos0 + j,
                                          cfg, block_table=table)
            seq.append(logits)
        seq_cache = cache

        cache = M.init_paged_cache(p, cfg, nb, bs)
        ver, ver_cache = M.verify_step(p, cache, tokens, pos0, cfg, table)

    assert ver.shape == (b, s, cfg.vocab_size)
    for j in range(s):
        np.testing.assert_array_equal(np.asarray(ver[:, j]),
                                      np.asarray(seq[j]))
    jax.tree.map(lambda a, b_: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b_)), seq_cache, ver_cache)


# ---------------------------------------------------------------------------
# refit_layer_spec at simultaneously reduced G AND K
# ---------------------------------------------------------------------------


def test_refit_reduced_grid_and_order(kan_setup):
    from repro.core.kan_layer import (KANSpec, bspline_basis, param_count)
    from repro.models.layers import kan_ffn_hidden, kan_ffn_spec
    from repro.serve.spec import refit_kan_ffn_params_tree

    cfg, params = kan_setup
    draft_cfg = dataclasses.replace(cfg, kan_grid=4, kan_order=2,
                                    kan_d_hidden=kan_ffn_hidden(cfg))
    old, new = kan_ffn_spec(cfg), kan_ffn_spec(draft_cfg)
    assert (new.grid_size, new.order) == (4, 2)
    assert (old.grid_size, old.order) == (cfg.kan_grid, cfg.kan_order)

    refit = refit_kan_ffn_params_tree(params, cfg, draft_cfg)
    blk = params["decoder"][0]["l0_ffn"]
    rblk = refit["decoder"][0]["l0_ffn"]
    # basis shrinks from G+K to G'+K' columns; edge geometry unchanged
    assert blk["c1"].shape[-2] == old.grid_size + old.order
    assert rblk["c1"].shape[-2] == new.grid_size + new.order
    assert blk["c1"].shape[:-2] == rblk["c1"].shape[:-2]
    # w_b rides through the refit untouched, bit for bit
    np.testing.assert_array_equal(np.asarray(blk["wb1"]),
                                  np.asarray(rblk["wb1"]))
    # deterministic replan: the same refit twice is bit-identical
    refit2 = refit_kan_ffn_params_tree(params, cfg, draft_cfg)
    np.testing.assert_array_equal(np.asarray(rblk["c1"]),
                                  np.asarray(refit2["decoder"][0]["l0_ffn"]["c1"]))
    # the reduced basis is the least-squares fit of the SAME spline: on the
    # shared knot domain the refit function tracks the original closely
    xs = jnp.linspace(old.lo, old.hi, 64)
    ob = bspline_basis(xs, old.lo, old.hi, old.grid_size, old.order)
    nb = bspline_basis(xs, new.lo, new.hi, new.grid_size, new.order)
    f_old = jnp.einsum("sn,fno->sfo", ob, blk["c1"][0])
    f_new = jnp.einsum("sn,fno->sfo", nb, rblk["c1"][0])

    def rms(a):
        return float(jnp.sqrt(jnp.mean(a * a)))

    # best-L2 fit onto the much smaller basis: captures well over half the
    # energy of the rough random-init splines (a zero fit would score 1.0)
    assert rms(f_old - f_new) < 0.5 * rms(f_old)

    # the paper's #Param convention shrinks with (G + K + 1)
    dims = (cfg.d_model, kan_ffn_hidden(cfg), cfg.d_model)
    n_t = param_count(KANSpec(dims=dims, grid_size=cfg.kan_grid,
                              order=cfg.kan_order))
    n_d = param_count(KANSpec(dims=dims, grid_size=4, order=2))
    assert n_d < n_t

    drafter = DraftModel(params, cfg, DraftSpec(grid=4, order=2),
                         slots=2, max_len=32)
    d = drafter.describe()
    assert (d["kan_grid"], d["kan_order"]) == (4, 2)
    assert d["ffn_params_per_block"] == n_d
    # the drafter keeps the target's layer geometry (hidden width pinned)
    assert drafter.cfg.kan_d_hidden == kan_ffn_hidden(cfg)


def test_draft_deploys_without_retracing_target_plans(kan_setup):
    """The drafter's reduced specs key SEPARATE plan-cache entries: serving
    the same workload spec-on after a spec-off warmup only ever traces NEW
    entries (every trace delta is an entry delta — no target entry is
    retraced), and the spec-off engine's plans replay as pure hits."""
    cfg, params = kan_setup
    runtime.reset_cache()
    serve(params, cfg, 0)                      # warm the target's plans
    s0 = runtime.cache_stats()
    out, _ = serve(params, cfg, 2, draft_spec="grid=4,order=2")
    s1 = runtime.cache_stats()
    d_traces = s1["traces"] - s0["traces"]
    d_entries = s1["entries"] - s0["entries"]
    assert d_entries > 0                       # the drafter added its plans
    assert d_traces == d_entries, (d_traces, d_entries)
    # replaying the spec engine hits both plan sets without a single trace
    s2 = runtime.cache_stats()
    serve(params, cfg, 2, draft_spec="grid=4,order=2")
    s3 = runtime.cache_stats()
    assert s3["traces"] == s2["traces"]
    assert s3["hits"] > s2["hits"]


# ---------------------------------------------------------------------------
# KV pool truncate: speculative rollback bookkeeping
# ---------------------------------------------------------------------------


def test_truncate_releases_whole_tail_blocks():
    pool = KVBlockPool(num_blocks=8, block_size=4)
    blocks = [pool.alloc() for _ in range(4)]  # covers 16 token positions
    keep = list(blocks)
    tail = pool.truncate(blocks, 9)            # ceil(9/4)=3 blocks stay
    assert blocks == keep[:3] and tail == keep[3:]
    assert pool.truncations == 1
    assert pool.blocks_in_use() == 3
    assert pool.truncate(blocks, 12) == []     # boundary: nothing to drop
    assert pool.truncate(blocks, 0) == keep[:3]
    assert pool.blocks_in_use() == 0
    with pytest.raises(ValueError):
        pool.truncate(blocks, -1)
    pool.check_consistent()
    assert pool.stats()["truncations"] == 4


def test_truncate_refuses_cached_prefix_blocks():
    pool = KVBlockPool(num_blocks=8, block_size=4)
    prompt = list(range(8))                    # two FULL published blocks
    blocks = [pool.alloc(), pool.alloc(), pool.alloc()]
    pool.publish_prefix(prompt, blocks[:2])
    with pytest.raises(ValueError, match="cached prefix"):
        pool.truncate(list(blocks), 4)         # would release published [1]
    # rollback over the request's OWN tail is fine right up to the boundary
    tail = pool.truncate(blocks, 8)
    assert len(tail) == 1
    pool.check_consistent()


# ---------------------------------------------------------------------------
# Scheduler metrics surface
# ---------------------------------------------------------------------------


def test_stats_tokens_per_round_and_spec_block(kan_setup):
    cfg, params = kan_setup
    eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                      spec_decode=2, **PAGED)
    sched = Scheduler(eng)
    reqs = make_reqs(cfg)
    for r in reqs:
        sched.submit(r)
    sched.run_until_idle()
    s = sched.stats()
    sp = s["spec"]
    assert sp["k"] == 2 and sp["rounds"] > 0
    assert 0 < sp["drafted"] <= 2 * eng.slots * sp["rounds"]
    assert 0 <= sp["accepted"] <= sp["drafted"]
    assert 0.0 <= sp["accept_rate"] <= 1.0
    assert sp["draft_s"]["p50"] > 0 and sp["verify_s"]["p50"] > 0
    # accepted drafts make rounds emit >1 token per active slot on average
    # (bounded by the k+1 rows a verify pass scores)
    assert 1.0 <= s["tokens_per_round"] <= 3.0
    # ITL is per EMITTED token: one gap per token after each first token
    assert s["itl_s"]["n"] == s["tokens"] - s["completed"]

    # spec off: the same surface degenerates exactly
    eng0 = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                       **PAGED)
    sched0 = Scheduler(eng0)
    for r in make_reqs(cfg):
        sched0.submit(r)
    sched0.run_until_idle()
    s0 = sched0.stats()
    assert s0["spec"] is None
    assert s0["tokens_per_round"] == 1.0
    assert s0["itl_s"]["n"] == s0["tokens"] - s0["completed"]


def test_spec_with_sampled_requests_emits_one_token_per_round(kan_setup):
    """Sampled slots ride spec rounds but emit exactly one token from the
    verify row (the classic per-position key schedule), excluded from the
    accept-rate counters; their streams reproduce the spec-off sampler."""
    from repro.serve.scheduler import SamplingParams

    cfg, params = kan_setup
    sp = SamplingParams(temperature=0.9, top_k=16, seed=7)

    def sampled(k):
        eng = ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                          spec_decode=k, **PAGED)
        sched = Scheduler(eng)
        rng = jax.random.PRNGKey(42)
        reqs = []
        for rid in range(2):
            rng, kk = jax.random.split(rng)
            prompt = jax.random.randint(kk, (5,), 3, cfg.vocab_size).tolist()
            reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=4,
                                sampling=sp))
        for r in reqs:
            sched.submit(r)
        sched.run_until_idle()
        return {r.rid: r.output for r in reqs}, sched.stats()

    base, _ = sampled(0)
    out, s = sampled(2)
    assert out == base
    assert s["spec"]["drafted"] == 0           # sampled slots never counted
    assert s["tokens_per_round"] == 1.0


# ---------------------------------------------------------------------------
# DraftSpec parsing / resolution
# ---------------------------------------------------------------------------


def test_draft_spec_parse_and_resolve(kan_setup):
    cfg, _ = kan_setup
    full = DraftSpec.parse("grid=4, order=2, bits=6, backend=ref")
    assert full == DraftSpec(grid=4, order=2, n_bits=6, backend="ref")
    assert DraftSpec.parse("n_bits=6") == DraftSpec(n_bits=6)
    assert DraftSpec.parse(None) == DraftSpec()
    assert DraftSpec.parse("") == DraftSpec()
    # defaults: halved grid (floored at 2), inherited order/bits
    g, o, b = DraftSpec().resolve(cfg)
    assert g == max(2, cfg.kan_grid // 2)
    assert (o, b) == (cfg.kan_order, cfg.kan_n_bits)
    tiny = dataclasses.replace(cfg, kan_grid=3)
    assert DraftSpec().resolve(tiny)[0] == 2
    with pytest.raises(ValueError, match="unknown"):
        DraftSpec.parse("grids=4")
    with pytest.raises(ValueError, match="key=value"):
        DraftSpec.parse("grid:4")
    with pytest.raises(ValueError, match=">= 1"):
        DraftSpec(grid=0).resolve(cfg)


def test_engine_rejects_inconsistent_spec_kwargs(kan_setup):
    cfg, params = kan_setup
    with pytest.raises(ValueError, match="spec_decode"):
        ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                    spec_decode=2)             # no paged KV
    with pytest.raises(ValueError, match="draft_spec"):
        ServeEngine(params, cfg, slots=2, max_len=32, kan_deploy=True,
                    draft_spec="grid=4", **PAGED)  # spec off
