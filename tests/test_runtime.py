"""repro.runtime: registry resolution, plan/compile cache, acim backend.

Covers the PR's dispatch contract:

  * one registry resolves every backend name (argument > use_backend scope >
    REPRO_KAN_BACKEND env var > call-site default), unknown names raise;
  * the plan cache buckets ragged batches — {3, 5, 7, 8} share ONE bucket
    plan and trace the compiled executor exactly once — and its keys
    distinguish residual_raw / quantization-spec changes;
  * the acim backend is bit-exact vs "pallas" when every non-ideality is
    zeroed, reproducible under a fixed PRNG key, and degrades KAN1
    knot-classification accuracy by only a bounded amount at the paper's
    measured sigmas (statistical envelope across 32 noise seeds);
  * mesh-sharded execution (PR 4): 1x1 and data-only meshes are bit-exact
    vs the unsharded path (pallas and quiet-acim), model-sharded runs keep
    bit-exact boundary codes and match the layered ref within tolerance,
    mesh/no-mesh plan-cache entries never collide, and non-divisible model
    axes fall back to replicated columns with a recorded reason.  The
    multi-device cases skip unless the host exposes >= 2 devices (CI forces
    8 via XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.core.cim import CIMConfig
from repro.core.kan_layer import KANSpec, init_kan_network, kan_network_apply
from repro.core.kan_network_deploy import (
    deploy_kan_ffn_stack,
    deploy_kan_network,
    kan_network_apply_ref,
    kan_network_deploy_apply,
    quantize_kan_network,
)
from repro.core.tmdv import TMDVConfig


@pytest.fixture(autouse=True)
def _fresh_cache():
    runtime.reset_cache()
    yield
    runtime.reset_cache()


from conftest import assert_bit_exact, kan1_bundle, run_pair


def _kan1(batch=8, seed=0, grid=5):
    return kan1_bundle(batch=batch, seed=seed, grid=grid)


# ----------------------------------------------------------------------------
# registry / resolution
# ----------------------------------------------------------------------------


def test_registry_lists_the_three_backends():
    assert set(runtime.available_backends()) >= {"ref", "pallas", "acim"}


def test_resolution_precedence(monkeypatch):
    assert runtime.resolve_backend("ref") == "ref"
    assert runtime.resolve_backend(None, default="pallas") == "pallas"
    monkeypatch.setenv(runtime.ENV_BACKEND_VAR, "acim")
    assert runtime.resolve_backend(None, default="pallas") == "acim"
    with runtime.use_backend("ref"):       # scope beats env
        assert runtime.resolve_backend(None) == "ref"
        with runtime.use_backend(None):    # None scope is a passthrough
            assert runtime.resolve_backend(None) == "ref"
        assert runtime.resolve_backend("pallas") == "pallas"  # arg beats all
    assert runtime.resolve_backend(None) == "acim"
    monkeypatch.setenv(runtime.ENV_BACKEND_VAR, "")
    assert runtime.resolve_backend(None, default="ref") == "ref"


def test_unknown_backend_raises():
    with pytest.raises(ValueError):
        runtime.resolve_backend("tpu-magic")
    with pytest.raises(ValueError):
        with runtime.use_backend("no-such-backend"):
            pass


def test_env_var_reroutes_kan_network_apply(monkeypatch):
    kspec, qparams, _ = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(1), (6, 17), minval=-1, maxval=1)
    monkeypatch.setenv(runtime.ENV_BACKEND_VAR, "pallas")
    runtime.reset_cache()
    y = kan_network_apply(None, x, kspec, quantized=True,
                          qparams_list=qparams, interpret=True)
    # the env var routed the default-backend call onto the fused executor
    assert runtime.cache_stats()["traces"] == 1
    ref = kan_network_apply_ref(qparams, x, kspec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------------------------
# plan / compile cache
# ----------------------------------------------------------------------------


def test_ragged_batches_share_one_bucket_and_one_trace():
    kspec, qparams, dep = _kan1()
    runtime.reset_cache()
    for bsz in (3, 5, 7, 8):
        x = jax.random.uniform(jax.random.PRNGKey(bsz), (bsz, 17),
                               minval=-1.0, maxval=1.0)
        y = kan_network_deploy_apply(dep, x, interpret=True)
        assert y.shape == (bsz, 14)
        ref = kan_network_apply_ref(qparams, x, kspec)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
    stats = runtime.cache_stats()
    assert stats["entries"] == 1, stats   # one bucket (8) for all four
    assert stats["misses"] == 1, stats
    assert stats["hits"] == 3, stats
    assert stats["traces"] == 1, stats    # the executor was traced ONCE


def test_bucket_batch_rounds_to_powers_of_two():
    assert [runtime.bucket_batch(b) for b in (1, 3, 8, 9, 130)] == \
        [8, 8, 8, 16, 256]
    with pytest.raises(ValueError):
        runtime.bucket_batch(0)


def test_cache_keys_distinguish_spec_and_residual_changes():
    x = jax.random.uniform(jax.random.PRNGKey(0), (4, 17), minval=-1, maxval=1)
    _, _, dep_g5 = _kan1(grid=5)
    _, _, dep_g8 = _kan1(grid=8)
    runtime.reset_cache()
    kan_network_deploy_apply(dep_g5, x, interpret=True)
    kan_network_deploy_apply(dep_g8, x, interpret=True)  # spec change
    stats = runtime.cache_stats()
    assert stats["entries"] == 2 and stats["traces"] == 2, stats

    # residual_raw change at identical dims/specs is a distinct key
    kspec = KANSpec(dims=(17, 17, 17), grid_size=5)
    qparams = quantize_kan_network(
        init_kan_network(jax.random.PRNGKey(1), kspec), kspec
    )
    dep_kan = deploy_kan_network(qparams, kspec, batch=4)
    dep_ffn = deploy_kan_ffn_stack(qparams, kspec.dims, kspec.layer_spec(),
                                   batch=4)
    runtime.reset_cache()
    kan_network_deploy_apply(dep_kan, x, interpret=True)
    kan_network_deploy_apply(dep_ffn, x, interpret=True)
    stats = runtime.cache_stats()
    assert stats["entries"] == 2 and stats["hits"] == 0, stats


def test_backends_keep_separate_cache_entries():
    _, qparams, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(2), (5, 17), minval=-1, maxval=1)
    runtime.reset_cache()
    kan_network_deploy_apply(dep, x, interpret=True, backend="pallas")
    kan_network_deploy_apply(dep, x, interpret=True, backend="ref")
    kan_network_deploy_apply(dep, x, interpret=True, backend="pallas")
    stats = runtime.cache_stats()
    assert stats["entries"] == 2 and stats["hits"] == 1, stats


def test_replan_is_a_cache_lookup():
    _, _, dep = _kan1(batch=8)
    dep2 = dep.replan(640)
    dep3 = dep.replan(640)
    assert dep2.plan is dep3.plan         # memoized, not rebuilt
    assert dep2.layers is dep.layers      # weights/padding are batch-agnostic
    assert dep2.plan.b == 640


# ----------------------------------------------------------------------------
# acim backend
# ----------------------------------------------------------------------------


def test_acim_zeroed_nonidealities_bit_exact_vs_pallas():
    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(3), (7, 17), minval=-1, maxval=1)
    y_p, codes_p = kan_network_deploy_apply(
        dep, x, interpret=True, backend="pallas", return_intermediates=True
    )
    y_a, codes_a = kan_network_deploy_apply(
        dep, x, interpret=True, backend="acim",
        cim=runtime.quiet_cim_config(), key=jax.random.PRNGKey(9),
        return_intermediates=True,
    )
    np.testing.assert_array_equal(np.asarray(y_a), np.asarray(y_p))
    for ca, cp in zip(codes_a, codes_p):
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cp))


def test_acim_noise_is_seeded_and_reproducible():
    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(4), (6, 17), minval=-1, maxval=1)
    cim = CIMConfig(ir_gamma=0.06, sigma_ps_ref=0.05)
    y_p = kan_network_deploy_apply(dep, x, interpret=True, backend="pallas")
    y1 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  cim=cim, key=jax.random.PRNGKey(0))
    y2 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  cim=cim, key=jax.random.PRNGKey(0))
    y3 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  cim=cim, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert float(jnp.abs(y1 - y3).max()) > 0.0
    assert float(jnp.abs(y1 - y_p).max()) > 0.0  # noise actually injected
    # key=None derives a deterministic key from the entry codes: same input
    # reproduces, different input decorrelates (serving has no key plumbing)
    y4 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  cim=cim)
    y5 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  cim=cim)
    np.testing.assert_array_equal(np.asarray(y4), np.asarray(y5))


def test_acim_deterministic_flag_keeps_irdrop_only():
    """deterministic=True: stochastic terms off, systematic IR-drop stays."""
    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(5), (6, 17), minval=-1, maxval=1)
    cim = CIMConfig(ir_gamma=0.06, sigma_ps_ref=0.05, deterministic=True)
    y1 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  cim=cim, key=jax.random.PRNGKey(0))
    y2 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  cim=cim, key=jax.random.PRNGKey(7))
    y_p = kan_network_deploy_apply(dep, x, interpret=True, backend="pallas")
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))  # no RNG
    assert float(jnp.abs(y1 - y_p).max()) > 0.0  # IR-drop residual present


def test_acim_accuracy_envelope_on_kan1_knot_task():
    """Paper-measured non-idealities cost only a bounded accuracy drop.

    Short-trains the paper's KAN1 (17,1,14 / G=5) on the knot surrogate,
    quantizes it, then compares "pallas" accuracy against "acim" accuracy
    across 32 noise seeds at the measured sigmas (examples/knot_e2e.py's
    calibration: ir_gamma=0.06, sigma_ps_ref=0.05, TD-A input generator).
    The assertion is a statistical envelope, not exact values.
    """
    from repro.core.neurosim import train_kan
    from repro.data.knot import make_knot_dataset

    xt, yt, xv, yv = make_knot_dataset(4096, 512, seed=0, label_noise=0.04)
    kspec = KANSpec(dims=(17, 1, 14), grid_size=5)
    params, _ = train_kan(kspec, xt, yt, xv, yv, epochs=60, batch_size=1024,
                          lr=1.5e-2, seed=0)
    qparams = quantize_kan_network(params, kspec)
    dep = deploy_kan_network(qparams, kspec, batch=len(xv))
    xv = jnp.asarray(xv)
    yv = np.asarray(yv)

    logits = kan_network_deploy_apply(dep, xv, interpret=True)
    acc_pallas = float((np.argmax(np.asarray(logits), -1) == yv).mean())
    assert acc_pallas > 3.0 / 14.0  # clearly above the 14-class chance floor

    cim = CIMConfig(ir_gamma=0.06, sigma_ps_ref=0.05)
    accs = []
    for seed in range(32):
        la = kan_network_deploy_apply(
            dep, xv, interpret=True, backend="acim", cim=cim,
            key=jax.random.PRNGKey(seed),
        )
        accs.append(float((np.argmax(np.asarray(la), -1) == yv).mean()))
    mean_acc = float(np.mean(accs))
    # envelope: non-idealities may cost a few points, never collapse the
    # model, and cannot systematically IMPROVE it beyond seed noise
    assert mean_acc >= acc_pallas - 0.10, (mean_acc, acc_pallas)
    assert mean_acc <= acc_pallas + 0.03, (mean_acc, acc_pallas)
    assert min(accs) >= acc_pallas - 0.15, (min(accs), acc_pallas)


# ----------------------------------------------------------------------------
# mesh-sharded execution
# ----------------------------------------------------------------------------

_N_DEV = len(jax.devices())
_NEED2 = pytest.mark.skipif(
    _N_DEV < 2,
    reason="needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _mesh(data=1, model=1):
    from repro.launch.mesh import make_local_mesh

    return make_local_mesh(data, model)


# shared with test_kvpool/test_spec_decode/test_mixed_precision via conftest
_run_pair = run_pair
_assert_bit_exact = assert_bit_exact


def test_sharded_1x1_mesh_bit_exact_vs_unsharded():
    """The degenerate mesh is the strongest plumbing check and runs on any
    host: shard_map + per-shard plan + boundary gather must be bitwise
    invisible for pallas AND quiet-acim."""
    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(6), (13, 17), minval=-1, maxval=1)
    a, b = _run_pair(dep, x, _mesh(1, 1))
    _assert_bit_exact(a, b)
    a, b = _run_pair(dep, x, _mesh(1, 1), backend="acim",
                     cim=runtime.quiet_cim_config(), key=jax.random.PRNGKey(9))
    _assert_bit_exact(a, b)


@_NEED2
def test_data_sharded_pallas_bit_exact():
    """Rows are independent through the whole datapath, so splitting the
    batch bucket over "data" must not move a single bit — outputs and the
    int boundary codes both."""
    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(7), (11, 17), minval=-1, maxval=1)
    a, b = _run_pair(dep, x, _mesh(data=2))
    _assert_bit_exact(a, b)


@_NEED2
def test_data_sharded_quiet_acim_bit_exact():
    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(8), (9, 17), minval=-1, maxval=1)
    a, b = _run_pair(dep, x, _mesh(data=2), backend="acim",
                     cim=runtime.quiet_cim_config(), key=jax.random.PRNGKey(3))
    _assert_bit_exact(a, b)


@_NEED2
def test_model_sharded_codes_bit_exact_outputs_close_to_ref():
    """Output-channel sharding: every shard owns whole MAC columns, so the
    shard-local boundary requantizer emits the same int codes; the final
    f32 output may re-tile its accumulation, so it is held to the layered
    reference at the existing tolerance."""
    kspec = KANSpec(dims=(17, 17, 17), grid_size=5)
    qparams = quantize_kan_network(
        init_kan_network(jax.random.PRNGKey(1), kspec), kspec
    )
    dep = deploy_kan_network(qparams, kspec, batch=8)
    x = jax.random.uniform(jax.random.PRNGKey(9), (8, 17), minval=-1, maxval=1)
    (y0, c0), (y1, c1) = _run_pair(
        dep, x, _mesh(data=max(1, _N_DEV // 4), model=2)
    )
    for x0, x1 in zip(c0, c1):
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x0))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               atol=1e-5, rtol=1e-5)
    ref = kan_network_apply_ref(qparams, x, kspec)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@_NEED2
def test_model_sharded_ref_backend_matches():
    kspec = KANSpec(dims=(17, 17, 17), grid_size=5)
    qparams = quantize_kan_network(
        init_kan_network(jax.random.PRNGKey(2), kspec), kspec
    )
    dep = deploy_kan_network(qparams, kspec, batch=8)
    x = jax.random.uniform(jax.random.PRNGKey(10), (6, 17), minval=-1, maxval=1)
    (y0, _), (y1, _) = _run_pair(dep, x, _mesh(1, 2), backend="ref")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               atol=1e-5, rtol=1e-5)


def test_mesh_and_unsharded_cache_entries_never_collide():
    """The PlanKey mesh fingerprint keeps sharded and unsharded compiled
    applies apart — and each re-resolution is a pure hit on its own entry."""
    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(11), (5, 17), minval=-1, maxval=1)
    mesh = _mesh(1, 1)
    runtime.reset_cache()
    kan_network_deploy_apply(dep, x, interpret=True)
    kan_network_deploy_apply(dep, x, interpret=True, mesh=mesh)
    stats = runtime.cache_stats()
    assert stats["entries"] == 2 and stats["misses"] == 2, stats
    kan_network_deploy_apply(dep, x, interpret=True)
    kan_network_deploy_apply(dep, x, interpret=True, mesh=mesh)
    stats = runtime.cache_stats()
    assert stats["entries"] == 2 and stats["hits"] == 2, stats
    assert stats["traces"] == 2, stats


@_NEED2
def test_acim_sharded_noise_seeded_and_reproducible():
    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(12), (6, 17), minval=-1, maxval=1)
    cim = CIMConfig(ir_gamma=0.06, sigma_ps_ref=0.05)
    mesh = _mesh(data=2)
    y1 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  mesh=mesh, cim=cim, key=jax.random.PRNGKey(0))
    y2 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  mesh=mesh, cim=cim, key=jax.random.PRNGKey(0))
    y3 = kan_network_deploy_apply(dep, x, interpret=True, backend="acim",
                                  mesh=mesh, cim=cim, key=jax.random.PRNGKey(1))
    y_p = kan_network_deploy_apply(dep, x, interpret=True, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert float(jnp.abs(y1 - y3).max()) > 0.0
    assert float(jnp.abs(y1 - y_p).max()) > 0.0  # noise actually injected


@pytest.mark.skipif(
    _N_DEV < 3, reason="needs a 3-wide model axis to force the fallback"
)
def test_model_axis_fallback_replicates_and_records_reason():
    """op=128 is not divisible by 3: every layer must fall back to
    replicated columns (recorded in shard_notes) and stay bit-exact."""
    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(13), (7, 17), minval=-1, maxval=1)
    runtime.reset_cache()
    a, b = _run_pair(dep, x, _mesh(1, 3))
    _assert_bit_exact(a, b)
    notes = [r for reasons in runtime.shard_notes().values() for r in reasons]
    assert notes and any("not shardable" in r for r in notes), notes


def test_use_mesh_scope_and_placement_resolution():
    """mesh= arg > use_mesh scope > DeployedKAN.placement, all bit-exact on
    the 1x1 mesh; replan keeps the placement."""
    from repro.core.kan_network_deploy import place_deployed_kan

    _, _, dep = _kan1()
    x = jax.random.uniform(jax.random.PRNGKey(14), (5, 17), minval=-1, maxval=1)
    mesh = _mesh(1, 1)
    y0 = kan_network_deploy_apply(dep, x, interpret=True)
    with runtime.use_mesh(mesh):
        y1 = kan_network_deploy_apply(dep, x, interpret=True)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
    placed = place_deployed_kan(dep, mesh)
    assert placed.placement is mesh
    assert placed.replan(64).placement is mesh
    y2 = kan_network_deploy_apply(placed, x, interpret=True)
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y0))
    # the placed bundle resolved through the mesh path: distinct cache key
    runtime.reset_cache()
    kan_network_deploy_apply(dep, x, interpret=True)
    kan_network_deploy_apply(placed, x, interpret=True)
    assert runtime.cache_stats()["entries"] == 2


# ----------------------------------------------------------------------------
# dist: deployed-bundle partition specs
# ----------------------------------------------------------------------------


def test_deployed_kan_pspecs_shard_output_channels():
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.dist.sharding import deployed_kan_pspecs

    _, _, dep = _kan1()
    devs = np.array(jax.devices() * 2)[:2].reshape(1, 2)
    mesh = Mesh(devs, ("data", "model"))  # abstract: only specs are inspected
    specs = deployed_kan_pspecs(dep, mesh)
    assert len(specs) == len(dep.layers)
    for s, lw in zip(specs, dep.layers):
        assert set(s) == {"lut", "wc", "wb"}
        # padded output channels are multiples of 128 -> sharded on "model";
        # the shared SH-LUT stays replicated
        assert s["wc"] == P(None, "model")
        assert s["wb"] == P(None, "model")
        assert s["lut"] == P(None, None)
