"""Pallas kan_spline kernel vs pure-jnp oracle: shape/dtype/grid sweeps.

Kernels run in interpret mode (CPU container); the BlockSpec tiling is the
TPU contract being validated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.asp_quant import ASPQuantSpec, build_lut, quantize_input
from repro.core.kan_layer import KANSpec, init_kan_network, quantize_kan_layer, kan_network_apply
from repro.kernels.kan_spline.ops import kan_spline, kan_spline_from_qparams
from repro.kernels.kan_spline.ref import kan_spline_ref


def _setup(B, F, O, G, order=3, n_bits=8, seed=0, wdtype=jnp.float32):
    spec = ASPQuantSpec(grid_size=G, order=order, n_bits=n_bits, lo=-1.0, hi=1.0)
    e = build_lut(spec)
    lut = jnp.asarray(e["lut_q"] * e["scale"], jnp.float32)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    codes = jax.random.randint(k1, (B, F), 0, spec.num_codes)
    wc = (jax.random.normal(k2, (F, spec.num_basis, O)) * 0.3).astype(wdtype)
    wb = (jax.random.normal(k3, (F, O)) * 0.3).astype(wdtype)
    return spec, lut, codes, wc, wb


SHAPES = [
    (32, 17, 14, 5),     # the paper's edge KAN layer
    (8, 3, 5, 8),        # tiny, heavy padding
    (130, 300, 200, 16), # multi-tile all axes
    (256, 128, 128, 4),  # exact tiles
    (1, 1, 1, 64),       # degenerate
]


@pytest.mark.parametrize("shape", SHAPES)
def test_kan_spline_matches_ref(shape):
    B, F, O, G = shape
    spec, lut, codes, wc, wb = _setup(B, F, O, G)
    ref = kan_spline_ref(codes, lut, wc, wb, spec)
    out = kan_spline(codes, lut, wc, wb, spec, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16])
def test_kan_spline_dtypes(wdtype):
    spec, lut, codes, wc, wb = _setup(64, 32, 48, 8, wdtype=wdtype)
    ref = kan_spline_ref(codes, lut, wc, wb, spec)
    out = kan_spline(codes, lut, wc, wb, spec, interpret=True)
    tol = 5e-2 if wdtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


@pytest.mark.parametrize("order", [1, 2, 3, 4])
def test_kan_spline_orders(order):
    spec, lut, codes, wc, wb = _setup(16, 8, 8, 6, order=order)
    ref = kan_spline_ref(codes, lut, wc, wb, spec)
    out = kan_spline(codes, lut, wc, wb, spec, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("blocks", [(8, 128, 8), (16, 256, 16), (64, 128, 32)])
def test_kan_spline_block_shapes(blocks):
    bb, bo, bf = blocks
    spec, lut, codes, wc, wb = _setup(48, 40, 200, 8)
    ref = kan_spline_ref(codes, lut, wc, wb, spec)
    out = kan_spline(codes, lut, wc, wb, spec,
                     block_b=bb, block_o=bo, block_f=bf, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 64),
    f=st.integers(1, 48),
    o=st.integers(1, 40),
    g=st.sampled_from([4, 5, 8, 16]),
    seed=st.integers(0, 1000),
)
def test_kan_spline_property_random_shapes(b, f, o, g, seed):
    spec, lut, codes, wc, wb = _setup(b, f, o, g, seed=seed)
    ref = kan_spline_ref(codes, lut, wc, wb, spec)
    out = kan_spline(codes, lut, wc, wb, spec, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4, rtol=1e-3)


def test_kernel_equals_quantized_layer_path():
    """End-to-end: kernel == kan_layer_apply_quantized on a real layer."""
    kspec = KANSpec(dims=(17, 14), grid_size=5)
    spec = kspec.layer_spec()
    key = jax.random.PRNGKey(0)
    params = init_kan_network(key, kspec)
    qp = quantize_kan_layer(params[0], spec)
    x = jax.random.uniform(key, (33, 17), minval=-1, maxval=1)
    codes = quantize_input(x, spec)
    out_kernel = kan_spline_from_qparams(codes, qp, spec, interpret=True)
    out_layer = kan_network_apply(None, x, kspec, quantized=True, qparams_list=[qp])
    np.testing.assert_allclose(
        np.asarray(out_kernel), np.asarray(out_layer), atol=2e-4, rtol=1e-4
    )
