"""LM KAN-FFN deployment path: ASP quantization + Pallas kernel must match
the float FFN within int8 tolerance (the paper's technique at LM width)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.core.kan_ffn_deploy import kan_ffn_apply_quantized, quantize_kan_ffn
from repro.models import layers as L


def test_quantized_kan_ffn_matches_float():
    cfg = smoke_config("qwen2.5-14b").kan_variant(grid=8)
    key = jax.random.PRNGKey(0)
    p = L.init_ffn(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32) * 0.5

    y_float = L.ffn(p, x, cfg)
    qffn = quantize_kan_ffn(p, cfg)
    y_q = kan_ffn_apply_quantized(qffn, x, cfg, interpret=True)

    assert y_q.shape == y_float.shape
    err = float(jnp.abs(y_float - y_q).max())
    scale = float(jnp.abs(y_float).max())
    assert err < 0.06 * scale + 0.02, (err, scale)


def test_quantized_kan_ffn_storage_is_int8_plus_hemi_lut():
    cfg = smoke_config("qwen2.5-14b").kan_variant(grid=8)
    p = L.init_ffn(jax.random.PRNGKey(1), cfg)
    qffn = quantize_kan_ffn(p, cfg)
    # ONE canonical form: the int8 + SH-LUT qparams.  The padded f32
    # pipeline copy that used to double deployed weight residency is gone —
    # the runtime derives it on demand inside its cached executors.
    assert set(qffn) == {"l1", "l2"}
    for half in ("l1", "l2"):
        assert qffn[half]["c_q"].dtype == jnp.int8
        assert qffn[half]["w_b_q"].dtype == jnp.int8
        spec = L.kan_ffn_spec(cfg)
        total = (spec.order + 1) * spec.codes_per_interval
        assert len(qffn[half]["hemi"]) == total // 2 + 1  # SH-LUT: half stored
