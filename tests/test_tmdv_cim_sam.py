"""TM-DV-IG / CIM non-ideality / KAN-SAM behavioral properties."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.asp_quant import ASPQuantSpec
from repro.core.cim import CIMConfig, cim_matmul, ideal_matmul
from repro.core.sam import (
    basis_activation_probability,
    identity_permutation,
    row_activation_weight,
    sam_permutation,
)
from repro.core.tmdv import (
    PURE_PWM,
    PURE_VOLTAGE,
    TD_A,
    TD_P,
    TMDVConfig,
    apply_input_noise,
    wl_latency_units,
)


def test_tmdv_noiseless_is_linear_identity():
    cfg = dataclasses.replace(TD_A(8), sigma_v_ref=0.0, sigma_t=0.0)
    codes = jnp.arange(256)
    q = apply_input_noise(codes, cfg, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(q), np.arange(256), atol=1e-5)


def test_tmdv_latency_ordering():
    # pure voltage: 1 slot; TM-DV: 2**N slots; pure PWM: 2**2N slots
    assert wl_latency_units(PURE_VOLTAGE(8)) == 1
    assert wl_latency_units(TMDVConfig(8, 4)) == 16
    assert wl_latency_units(PURE_PWM(8)) == 256
    assert wl_latency_units(PURE_PWM(8)) // wl_latency_units(TMDVConfig(8, 4)) == 16


def test_tda_less_noise_than_tdp():
    """TD-A (fewer voltage levels) must have lower charge error than TD-P."""
    key = jax.random.PRNGKey(0)
    codes = jnp.arange(256).repeat(200)
    errs = {}
    for name, cfg in [("a", TD_A(8)), ("p", TD_P(8))]:
        q = apply_input_noise(codes, cfg, key)
        errs[name] = float(jnp.abs(q - codes.astype(jnp.float32)).mean())
    assert errs["a"] < errs["p"]


def test_pure_voltage_noisier_than_tmdv():
    key = jax.random.PRNGKey(1)
    codes = jnp.arange(256).repeat(200)
    qv = apply_input_noise(codes, PURE_VOLTAGE(8), key)
    qt = apply_input_noise(codes, TMDVConfig(8, 4), key)
    ev = float(jnp.abs(qv - codes.astype(jnp.float32)).mean())
    et = float(jnp.abs(qt - codes.astype(jnp.float32)).mean())
    assert ev > et


def test_ir_drop_error_grows_with_array_size():
    """Monotone in array size (paper Fig. 12).  The residual after mean-drop
    compensation is a random covariance between (x*w) and row distance, so a
    tiny 8x20 sample is dominated by draw noise — estimate over a 64x64 MAC
    with independent draws per size."""
    key = jax.random.PRNGKey(0)
    errs = []
    for rows in [128, 256, 512, 1024]:
        kx, kw = jax.random.split(jax.random.fold_in(key, rows))
        x = jax.random.uniform(kx, (64, rows), maxval=255.0)
        w = jax.random.randint(kw, (rows, 64), -127, 128).astype(jnp.float32)
        cfg = CIMConfig(array_rows=rows, adc_bits=12, ir_gamma=0.04,
                        deterministic=True)
        y = cim_matmul(x, w, cfg, key)
        yi = ideal_matmul(x, w)
        errs.append(float(jnp.abs(y - yi).mean() / jnp.abs(yi).mean()))
    assert errs == sorted(errs), errs


def test_activation_probability_k_plus_1_active():
    spec = ASPQuantSpec(grid_size=8, order=3, n_bits=8, lo=-1.0, hi=1.0)
    x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, 4000), jnp.float32)
    p = basis_activation_probability(x, spec)
    assert p.shape == (11,)
    # each input activates exactly K+1 bases
    np.testing.assert_allclose(float(p.sum()), spec.order + 1, atol=1e-5)
    # uniform inputs: interior bases more probable than edge bases
    assert p[0] < p[5] and p[-1] < p[5]


def test_sam_puts_probable_rows_at_compensated_mean():
    """Placement contract: drive decreases with a slot's distance from the
    digitally-compensated mean distance (cim.py's per-column correction), so
    the heavy rows sit where the correction cancels their attenuation."""
    spec = ASPQuantSpec(grid_size=8, order=3, n_bits=8, lo=-1.0, hi=1.0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.clip(rng.normal(0, 0.3, (4000, 3)), -1, 1), jnp.float32)
    rw = row_activation_weight(x, spec, 3)
    perm = sam_permutation(rw)
    w = np.asarray(rw)
    r = len(w)
    dist = (np.arange(r) + 1.0) / r
    mismatch = np.abs(dist - (r + 1.0) / (2.0 * r))
    order = np.argsort(mismatch, kind="stable")
    # the best-matched slot holds the highest-drive logical row, and drive
    # is non-increasing as the slot mismatch grows
    assert w[perm[order[0]]] == w.max()
    assert (np.diff(w[perm[order]]) <= 1e-9).all()
    assert sorted(perm) == list(range(r))  # a permutation, nothing dropped


def test_sam_improves_accuracy_under_ir_drop():
    """The Fig. 12 mechanism: same MAC, SAM placement, lower error."""
    spec = ASPQuantSpec(grid_size=30, order=3, n_bits=8, lo=-1.0, hi=1.0)
    rng = np.random.default_rng(0)
    f = 17
    xs = jnp.asarray(np.clip(rng.normal(0, 0.35, (256, f)), -1, 1), jnp.float32)
    from repro.core.asp_quant import build_lut, dense_basis_from_codes, quantize_input

    e = build_lut(spec)
    lut = jnp.asarray(e["lut_q"] * e["scale"], jnp.float32)
    codes = quantize_input(xs, spec)
    basis = dense_basis_from_codes(codes, lut, spec)
    drives = basis.reshape(256, -1) * 255.0
    w = jnp.asarray(rng.integers(-127, 128, (f * spec.num_basis, 14)), jnp.float32)

    ideal = ideal_matmul(drives, w)
    cfg = CIMConfig(array_rows=512, adc_bits=10, ir_gamma=0.08, deterministic=True)
    key = jax.random.PRNGKey(0)
    base = cim_matmul(drives, w, cfg, key, row_perm=None, x_max=255.0,
                      adc_calibrate=True)
    rw = row_activation_weight(xs, spec, f)
    sam = cim_matmul(drives, w, cfg, key, row_perm=sam_permutation(rw, 512),
                     x_max=255.0, adc_calibrate=True)
    err_base = float(jnp.abs(base - ideal).mean())
    err_sam = float(jnp.abs(sam - ideal).mean())
    assert err_sam < err_base, (err_sam, err_base)
